//! The schedule compiler: lower a [`Plan`] into an explicit
//! [`StepSchedule`] that both trainers and the serving engine
//! *execute*, instead of re-deriving the op/buffer sequence
//! imperatively each step.
//!
//! A schedule has three parts:
//!
//! - **ops** — the flat forward / backward instruction lists
//!   ([`OpInstr`]): op kind + operand geometry + the weight index,
//!   with no-op layers (`Flatten`) eliminated and the backward list
//!   pre-reversed into execution order;
//! - **passes** — per pass (train / eval / per-batch infer), the exact
//!   arena event stream ([`BufEvent`]): every `take` and `put` the
//!   engine will perform, in order, with the arena **slot index** each
//!   buffer lives in.  A pass stores one chunk's events plus a repeat
//!   count (microbatched steps replay the chunk), and an optional tail
//!   (the proposed engine's post-update residual drain when the step
//!   is a single chunk);
//! - **slots** — per typed pool (f32 / u64 bit panels / f16 carriers /
//!   u32 masks), the slot capacities produced by greedy
//!   lifetime-overlap interval coloring: two transients with disjoint
//!   live ranges share one slot, so the arena shrinks below the old
//!   best-fit free-list fixed point (kept here as `uncolored_bytes`
//!   for comparison and CI gating).
//!
//! The compiler walks the plan with pure shape arithmetic — no engine
//! is constructed, nothing is allocated at model scale — mirroring the
//! engines' checkout choreography symbolically.  The executor
//! ([`super::arena::StepArena`]) then asserts every runtime take/put
//! against the stream, so any divergence between compiler and engine
//! is an immediate panic (caught by the `engine_parity` sweep), not a
//! silent drift.  `memmodel::{step_envelope,serve_envelope}` fold over
//! the compiled slot table, making the planned arena bytes exact by
//! construction.
//!
//! Schedules are serializable to JSON (via the in-repo `util::json`,
//! deterministic key order), diffable, and dumpable with
//! `bnn-edge schedule` / `--dump-schedule`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::plan::{LayerPlan, Plan, SkipGeom};
use crate::bitops::ConvGeom;
use crate::util::json::Json;

/// Number of typed arena pools.
pub const POOLS: usize = 4;

/// Typed arena pool a buffer lives in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    /// f32 activations / transients.
    F32,
    /// u64 words: packed bit panels and bit masks.
    U64,
    /// u16 words: f16 gradient carriers and retained BN statistics.
    F16,
    /// u32 words: max-pool argmax masks.
    U32,
}

impl PoolKind {
    pub const ALL: [PoolKind; POOLS] =
        [PoolKind::F32, PoolKind::U64, PoolKind::F16, PoolKind::U32];

    pub fn idx(self) -> usize {
        match self {
            PoolKind::F32 => 0,
            PoolKind::U64 => 1,
            PoolKind::F16 => 2,
            PoolKind::U32 => 3,
        }
    }

    pub fn elem_bytes(self) -> usize {
        match self {
            PoolKind::F32 => 4,
            PoolKind::U64 => 8,
            PoolKind::F16 => 2,
            PoolKind::U32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PoolKind::F32 => "f32",
            PoolKind::U64 => "u64",
            PoolKind::F16 => "f16",
            PoolKind::U32 => "u32",
        }
    }

    fn parse(s: &str) -> Result<PoolKind> {
        Ok(match s {
            "f32" => PoolKind::F32,
            "u64" => PoolKind::U64,
            "f16" => PoolKind::F16,
            "u32" => PoolKind::U32,
            other => bail!("unknown pool kind '{other}'"),
        })
    }
}

/// How a taken buffer is initialised.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TakeInit {
    /// Resized to length, contents unspecified (fully overwritten).
    Raw,
    /// Zero-filled.
    Zeroed,
    /// Filled by copying a caller-provided source slice.
    Copy,
}

impl TakeInit {
    fn code(self) -> &'static str {
        match self {
            TakeInit::Raw => "r",
            TakeInit::Zeroed => "z",
            TakeInit::Copy => "c",
        }
    }

    fn parse(s: &str) -> Result<TakeInit> {
        Ok(match s {
            "r" => TakeInit::Raw,
            "z" => TakeInit::Zeroed,
            "c" => TakeInit::Copy,
            other => bail!("unknown take init '{other}'"),
        })
    }
}

/// One arena event: a checkout or a return, bound to a slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufEvent {
    Take { pool: PoolKind, slot: usize, len: usize, init: TakeInit },
    Put { pool: PoolKind, slot: usize },
}

/// One lowered instruction.  `Matmul` embeds its (cloned) layer plan
/// so a schedule is self-contained; `wi` is the weight index,
/// precomputed at lowering (the backward list carries it too, so the
/// driver never counts weight layers).
#[derive(Clone, Debug, PartialEq)]
pub enum OpInstr {
    Matmul { wi: usize, layer: LayerPlan },
    MaxPool { h: usize, w: usize, c: usize, kside: usize, stride: usize },
    GlobalPool { h: usize, w: usize, c: usize },
    SkipSave,
    SkipClose { skip: SkipGeom },
}

/// The event stream of one pass.  `events` covers **one chunk**; the
/// executor replays it `repeats` times, then runs `tail` (non-empty
/// only for the proposed engine's single-chunk train pass, whose
/// retained residuals drain after the optimizer update).
#[derive(Clone, Debug, PartialEq)]
pub struct PassEvents {
    pub name: String,
    pub repeats: usize,
    pub events: Vec<BufEvent>,
    pub tail: Vec<BufEvent>,
}

/// Per-pool slot capacities (element counts) shared by every pass of a
/// schedule.  Passes never overlap in time, so one table serves all.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SlotTable {
    pub caps: [Vec<usize>; POOLS],
}

impl SlotTable {
    pub fn pool_bytes(&self, p: PoolKind) -> usize {
        self.caps[p.idx()].iter().sum::<usize>() * p.elem_bytes()
    }

    pub fn total_bytes(&self) -> usize {
        PoolKind::ALL.iter().map(|&p| self.pool_bytes(p)).sum()
    }

    pub fn slot_count(&self) -> usize {
        self.caps.iter().map(Vec::len).sum()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// A trainer's step + eval schedule.
    Step,
    /// A serving engine's per-batch infer + eval schedule.
    Serve,
}

/// A compiled, executable, serializable schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct StepSchedule {
    pub kind: ScheduleKind,
    pub model: String,
    pub algo: String,
    /// Naive accelerator tier (changes the kernel buffer choreography).
    pub naive: bool,
    /// `Step`: microbatch (rows per chunk).  `Serve`: max batch.
    pub micro: usize,
    /// `Step`: chunks per step (batch / micro).  `Serve`: 1.
    pub chunks: usize,
    pub input_elems: usize,
    pub classes: usize,
    pub fwd_ops: Vec<OpInstr>,
    /// Backward instructions in execution order (already reversed).
    pub bwd_ops: Vec<OpInstr>,
    pub slots: SlotTable,
    /// `Step`: `[train, eval]`.  `Serve`: `[infer_1..infer_B,
    /// eval_1..eval_B]`.
    pub passes: Vec<Arc<PassEvents>>,
    /// What the old per-pass best-fit free list would have pooled —
    /// the uncolored baseline the coloring must beat (CI-gated).
    pub uncolored_bytes: usize,
}

impl StepSchedule {
    /// Colored arena footprint: the sum of all slot capacities.
    pub fn arena_bytes(&self) -> usize {
        self.slots.total_bytes()
    }

    pub fn slot_count(&self) -> usize {
        self.slots.slot_count()
    }

    pub fn train_pass(&self) -> &Arc<PassEvents> {
        &self.passes[0]
    }

    pub fn eval_pass(&self) -> &Arc<PassEvents> {
        &self.passes[1]
    }

    /// Serve schedules: the infer pass for batch `b` (1-based).
    pub fn infer_pass(&self, b: usize) -> &Arc<PassEvents> {
        &self.passes[b - 1]
    }

    /// Serve schedules: the eval pass for batch `b` (1-based).
    pub fn serve_eval_pass(&self, b: usize) -> &Arc<PassEvents> {
        &self.passes[self.micro + b - 1]
    }

    pub fn pass(&self, name: &str) -> Option<&Arc<PassEvents>> {
        self.passes.iter().find(|p| p.name == name)
    }

    /// One-line human summary: slot count, colored arena bytes per
    /// typed pool, and the coloring's savings vs the old per-pass
    /// best-fit free list.  Printed by `bnn-edge schedule` and the
    /// multi-tenant CLI demo.
    pub fn summary(&self) -> String {
        let colored = self.arena_bytes();
        let uncolored = self.uncolored_bytes;
        let saved = uncolored.saturating_sub(colored);
        let pct = if uncolored > 0 {
            100.0 * saved as f64 / uncolored as f64
        } else {
            0.0
        };
        let pools: Vec<String> = PoolKind::ALL
            .iter()
            .filter(|&&p| self.slots.pool_bytes(p) > 0)
            .map(|&p| {
                format!("{} {:.1} KiB", p.name(), self.slots.pool_bytes(p) as f64 / 1024.0)
            })
            .collect();
        format!(
            "{:>9}: {} slots, colored {:.1} KiB vs best-fit {:.1} KiB (-{pct:.1}%)  [{}]",
            self.algo,
            self.slot_count(),
            colored as f64 / 1024.0,
            uncolored as f64 / 1024.0,
            pools.join(", ")
        )
    }
}

// --------------------------------------------------------- lowering

/// Lower a plan to the flat forward and backward instruction lists.
/// `Flatten` is a no-op in both directions and is eliminated; weight
/// indices are baked in so drivers never re-count weight layers.
pub fn lower_ops(plan: &Plan) -> (Vec<OpInstr>, Vec<OpInstr>) {
    let mut fwd = Vec::new();
    let mut wi = 0usize;
    for layer in &plan.layers {
        match layer {
            LayerPlan::Dense { .. } | LayerPlan::Conv { .. } => {
                fwd.push(OpInstr::Matmul { wi, layer: layer.clone() });
                wi += 1;
            }
            LayerPlan::MaxPool { h, w, c, kside, stride, .. } => fwd.push(OpInstr::MaxPool {
                h: *h,
                w: *w,
                c: *c,
                kside: *kside,
                stride: *stride,
            }),
            LayerPlan::GlobalPool { h, w, c } => {
                fwd.push(OpInstr::GlobalPool { h: *h, w: *w, c: *c })
            }
            LayerPlan::Residual { save: true, .. } => fwd.push(OpInstr::SkipSave),
            LayerPlan::Residual { save: false, skip } => {
                fwd.push(OpInstr::SkipClose { skip: *skip })
            }
            LayerPlan::Flatten => {}
        }
    }
    let bwd: Vec<OpInstr> = fwd.iter().rev().cloned().collect();
    (fwd, bwd)
}

// --------------------------------------- symbolic event emission

const NONE_ID: usize = usize::MAX;

/// A symbolic buffer: pool + virtual id + element length.  `NONE_ID`
/// marks the empty buffer (len-0 takes emit no event, mirroring the
/// arena's `take(0) -> Vec::new()` rule).
#[derive(Clone, Copy)]
struct SBuf {
    pool: PoolKind,
    id: usize,
    len: usize,
}

impl SBuf {
    fn empty(pool: PoolKind) -> SBuf {
        SBuf { pool, id: NONE_ID, len: 0 }
    }
}

#[derive(Clone, Copy)]
struct RawEv {
    take: bool,
    pool: PoolKind,
    id: usize,
    len: usize,
    init: TakeInit,
}

/// The symbolic arena: assigns virtual buffer ids and records the
/// event stream.  Mirrors the arena's edge rules: len-0 takes return
/// the empty buffer without an event, puts of empty buffers are
/// skipped without an event.
#[derive(Default)]
struct Sym {
    raw: Vec<RawEv>,
    next: usize,
}

impl Sym {
    fn take(&mut self, pool: PoolKind, len: usize, init: TakeInit) -> SBuf {
        if len == 0 {
            return SBuf::empty(pool);
        }
        let id = self.next;
        self.next += 1;
        self.raw.push(RawEv { take: true, pool, id, len, init });
        SBuf { pool, id, len }
    }

    fn put(&mut self, b: SBuf) {
        if b.id == NONE_ID {
            return;
        }
        self.raw
            .push(RawEv { take: false, pool: b.pool, id: b.id, len: b.len, init: TakeInit::Raw });
    }

    fn f32(&mut self, len: usize) -> SBuf {
        self.take(PoolKind::F32, len, TakeInit::Raw)
    }

    fn zeroed_f32(&mut self, len: usize) -> SBuf {
        self.take(PoolKind::F32, len, TakeInit::Zeroed)
    }

    fn copy_f32(&mut self, len: usize) -> SBuf {
        self.take(PoolKind::F32, len, TakeInit::Copy)
    }

    fn u32(&mut self, len: usize) -> SBuf {
        self.take(PoolKind::U32, len, TakeInit::Raw)
    }

    fn f16(&mut self, len: usize) -> SBuf {
        self.take(PoolKind::F16, len, TakeInit::Raw)
    }

    /// Packed bit panel `rows × cols`: u64 words.
    fn bits(&mut self, rows: usize, cols: usize) -> SBuf {
        self.take(PoolKind::U64, rows * cols.div_ceil(64), TakeInit::Raw)
    }

    fn zeroed_bits(&mut self, rows: usize, cols: usize) -> SBuf {
        self.take(PoolKind::U64, rows * cols.div_ceil(64), TakeInit::Zeroed)
    }

    /// Bit mask over `len_bits` flags: zeroed u64 words.
    fn mask(&mut self, len_bits: usize) -> SBuf {
        self.take(PoolKind::U64, len_bits.div_ceil(64), TakeInit::Zeroed)
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Std,
    Prop,
    ServeStd,
    ServeProp,
}

#[derive(Default)]
struct SymRes {
    xhat: Option<SBuf>,
    x_first: Option<SBuf>,
    ste: Option<SBuf>,
    bn_sign: Option<SBuf>,
    psi: Option<SBuf>,
    omega: Option<SBuf>,
    dw_sign: Option<SBuf>,
}

/// Symbolic twin of the engines: replays each engine's checkout
/// choreography with shape arithmetic only.  Every branch here mirrors
/// a branch in `standard.rs` / `proposed.rs` / `serve/engine.rs`; the
/// executor's per-event asserts turn any divergence into a loud panic
/// under the parity sweeps.
struct SymEngine {
    sym: Sym,
    mode: Mode,
    naive: bool,
    micro: usize,
    single: bool,
    input_elems: usize,
    classes: usize,
    // standard trainer retained chunk state
    acts: Vec<SBuf>,
    bn_mu: Vec<SBuf>,
    bn_psi: Vec<SBuf>,
    pool_masks_u32: Vec<SBuf>,
    // proposed trainer retained residuals
    res: Vec<SymRes>,
    pool_masks_bits: Vec<SBuf>,
    // shared skip stacks
    skips: Vec<SBuf>,
    skip_grads: Vec<SBuf>,
}

impl SymEngine {
    fn new(
        mode: Mode,
        naive: bool,
        micro: usize,
        single: bool,
        input_elems: usize,
        classes: usize,
    ) -> SymEngine {
        SymEngine {
            sym: Sym::default(),
            mode,
            naive,
            micro,
            single,
            input_elems,
            classes,
            acts: Vec::new(),
            bn_mu: Vec::new(),
            bn_psi: Vec::new(),
            pool_masks_u32: Vec::new(),
            res: Vec::new(),
            pool_masks_bits: Vec::new(),
            skips: Vec::new(),
            skip_grads: Vec::new(),
        }
    }

    fn geom(&self, layer: &LayerPlan) -> (usize, usize, usize, bool, Option<ConvGeom>) {
        let b = self.micro;
        match *layer {
            LayerPlan::Dense { k, n, first } => (b, k, n, first, None),
            LayerPlan::Conv { g, cout, first } => (g.rows(b), g.k(), cout, first, Some(g)),
            _ => unreachable!("matmul instr on a non-matmul layer"),
        }
    }

    // ---- shared driver (mirrors ops::forward_plan / backward_plan)

    fn forward(&mut self, ops: &[OpInstr], retain: bool) -> SBuf {
        let m = self.micro;
        let mut cur = self.sym.copy_f32(m * self.input_elems);
        for op in ops {
            match op {
                OpInstr::Matmul { wi, layer } => {
                    cur = match self.mode {
                        Mode::Std | Mode::ServeStd => self.std_fwd(cur, layer, retain),
                        Mode::Prop => self.prop_fwd(cur, layer, retain),
                        Mode::ServeProp => self.serve_prop_fwd(cur, layer),
                    };
                    let _ = wi;
                }
                OpInstr::MaxPool { h, w, c, kside, stride } => {
                    cur = self.pool_fwd(cur, *h, *w, *c, *kside, *stride, retain);
                }
                OpInstr::GlobalPool { c, .. } => {
                    let out = self.sym.f32(m * c);
                    self.sym.put(cur);
                    cur = out;
                }
                OpInstr::SkipSave => {
                    let s = self.sym.copy_f32(cur.len);
                    self.skips.push(s);
                }
                OpInstr::SkipClose { .. } => {
                    let s = self.skips.pop().expect("skip stack underflow");
                    self.sym.put(s);
                }
            }
        }
        cur
    }

    fn backward(&mut self, bwd_ops: &[OpInstr], dlogits: SBuf) {
        let m = self.micro;
        let mut dcur = self.grad_from_f32(dlogits);
        for op in bwd_ops {
            match op {
                OpInstr::Matmul { wi, layer } => {
                    let d = self.grad_to_f32(dcur);
                    let dx = match self.mode {
                        Mode::Std => self.std_bwd(d, *wi, layer),
                        Mode::Prop => self.prop_bwd(d, *wi, layer),
                        _ => unreachable!("backward in a serve schedule"),
                    };
                    dcur = self.grad_from_f32(dx);
                }
                OpInstr::MaxPool { h, w, c, kside, stride } => {
                    let d = self.grad_to_f32(dcur);
                    let dx = self.pool_bwd(d, *h, *w, *c, *kside, *stride);
                    dcur = self.grad_from_f32(dx);
                }
                OpInstr::GlobalPool { h, w, c } => {
                    let d = self.grad_to_f32(dcur);
                    let dx = self.sym.f32(m * h * w * c);
                    self.sym.put(d);
                    dcur = self.grad_from_f32(dx);
                }
                OpInstr::SkipClose { skip } => {
                    let d = self.grad_to_f32(dcur);
                    let sg = self.sym.zeroed_f32(m * skip.h * skip.w * skip.c);
                    self.skip_grads.push(sg);
                    dcur = self.grad_from_f32(d);
                }
                OpInstr::SkipSave => {
                    let d = self.grad_to_f32(dcur);
                    let g = self.skip_grads.pop().expect("skip grad underflow");
                    self.sym.put(g);
                    dcur = self.grad_from_f32(d);
                }
            }
        }
        self.recycle_grad(dcur);
    }

    // ---- inter-layer gradient carrier conversions

    fn grad_to_f32(&mut self, g: SBuf) -> SBuf {
        match self.mode {
            Mode::Prop => {
                let v = self.sym.f32(g.len);
                self.sym.put(g);
                v
            }
            _ => g,
        }
    }

    fn grad_from_f32(&mut self, v: SBuf) -> SBuf {
        match self.mode {
            Mode::Prop => {
                let h = self.sym.f16(v.len);
                self.sym.put(v);
                h
            }
            _ => v,
        }
    }

    fn recycle_grad(&mut self, g: SBuf) {
        self.sym.put(g);
    }

    // ---- max-pool (identical event shapes across engines; only the
    // retained mask representation differs)

    fn pool_fwd(
        &mut self,
        cur: SBuf,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
        retain: bool,
    ) -> SBuf {
        let b = self.micro;
        let (oh, ow) = ((h - kside) / stride + 1, (w - kside) / stride + 1);
        let cells = b * oh * ow * c;
        let out = self.sym.f32(cells);
        let mask = self.sym.u32(cells);
        self.sym.put(cur);
        match self.mode {
            Mode::Std if retain => self.pool_masks_u32.push(mask),
            // the proposed engine's 1-bit was-max mask is only
            // unambiguous for non-overlapping 2×2 stride-2 windows;
            // general pools retain the u32 winner index instead
            Mode::Prop if retain && (kside, stride) == (2, 2) => {
                let bits = self.sym.mask(b * h * w * c);
                self.pool_masks_bits.push(bits);
                self.sym.put(mask);
            }
            Mode::Prop if retain => self.pool_masks_u32.push(mask),
            _ => self.sym.put(mask),
        }
        out
    }

    fn pool_bwd(
        &mut self,
        dnext: SBuf,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
    ) -> SBuf {
        let b = self.micro;
        let mask = match self.mode {
            Mode::Std => self.pool_masks_u32.pop().expect("pool mask underflow"),
            Mode::Prop if (kside, stride) == (2, 2) => {
                self.pool_masks_bits.pop().expect("pool mask underflow")
            }
            Mode::Prop => self.pool_masks_u32.pop().expect("pool mask underflow"),
            _ => unreachable!(),
        };
        let dx = self.sym.zeroed_f32(b * h * w * c);
        self.sym.put(mask);
        self.sym.put(dnext);
        dx
    }

    // ---- standard engine (trainer forward doubles as the serving
    // standard forward: their event streams are identical at
    // retain=false)

    fn std_fwd(&mut self, cur: SBuf, layer: &LayerPlan, retain: bool) -> SBuf {
        let b = self.micro;
        let (y, rows, n) = match *layer {
            LayerPlan::Dense { k, n, first } => {
                let y = self.sym.f32(b * n);
                if first || self.naive {
                    let bw = self.sym.f32(k * n);
                    if !first {
                        let a = self.sym.f32(cur.len);
                        self.sym.put(a);
                    }
                    self.sym.put(bw);
                } else {
                    let xhat = self.sym.bits(b, k);
                    self.sym.put(xhat);
                }
                (y, b, n)
            }
            LayerPlan::Conv { g, cout, first } => {
                let rows = g.rows(b);
                let y;
                if first || self.naive {
                    let bw = self.sym.f32(g.k() * cout);
                    if self.naive {
                        y = self.sym.zeroed_f32(rows * cout);
                        if !first {
                            let a = self.sym.f32(cur.len);
                            self.sym.put(a);
                        }
                    } else {
                        // fused first conv: rows×cin tap panel, no
                        // rows×k cols
                        y = self.sym.f32(rows * cout);
                        let panel = self.sym.f32(rows * g.cin);
                        self.sym.put(panel);
                    }
                    self.sym.put(bw);
                } else {
                    y = self.sym.f32(rows * cout);
                    let xhat = self.sym.bits(rows, g.k());
                    let scratch = self.sym.f32(g.kside * g.kside * cout);
                    self.sym.put(scratch);
                    self.sym.put(xhat);
                }
                (y, rows, cout)
            }
            _ => unreachable!(),
        };
        let xn = self.sym.f32(rows * n);
        let mu = self.sym.f32(n);
        let psi = self.sym.f32(n);
        self.sym.put(y);
        if retain {
            self.acts.push(cur);
            self.bn_mu.push(mu);
            self.bn_psi.push(psi);
            let keep = self.sym.copy_f32(xn.len);
            self.acts.push(keep);
        } else {
            self.sym.put(cur);
            self.sym.put(mu);
            self.sym.put(psi);
        }
        xn
    }

    fn std_bwd(&mut self, dnext: SBuf, _wi: usize, layer: &LayerPlan) -> SBuf {
        let b = self.micro;
        let direct = self.single;
        let (rows, _, n, _, _) = self.geom(layer);
        let dy = self.sym.f32(rows * n);
        let mv = self.sym.f32(n);
        let mvx = self.sym.f32(n);
        self.sym.put(mv);
        self.sym.put(mvx);
        self.sym.put(dnext);
        let dx_out = match *layer {
            LayerPlan::Dense { k, n, first } => {
                let dx_out = if first {
                    SBuf::empty(PoolKind::F32)
                } else {
                    let wt_f = self.sym.f32(n * k);
                    let dx = self.sym.f32(rows * k);
                    self.sym.put(wt_f);
                    dx
                };
                if direct {
                    self.std_dense_dw(rows, k, n, first);
                } else {
                    let dw = self.sym.f32(k * n);
                    self.std_dense_dw(rows, k, n, first);
                    self.sym.put(dw);
                }
                dx_out
            }
            LayerPlan::Conv { g, cout, first } => {
                let k = g.k();
                let fused = !first && !self.naive;
                let dx_out = if first {
                    SBuf::empty(PoolKind::F32)
                } else if fused {
                    let dx = self.sym.zeroed_f32(g.in_len(b));
                    let panel = self.sym.f32(rows * g.cin);
                    let wtap = self.sym.f32(cout * g.cin);
                    self.sym.put(panel);
                    self.sym.put(wtap);
                    dx
                } else {
                    let wt_f = self.sym.f32(cout * k);
                    let dcols = self.sym.f32(rows * k);
                    self.sym.put(wt_f);
                    let dx = self.sym.zeroed_f32(g.in_len(b));
                    self.sym.put(dcols);
                    dx
                };
                if direct {
                    self.std_conv_dw(b, g, cout, first);
                } else {
                    let dw = self.sym.f32(k * cout);
                    self.std_conv_dw(b, g, cout, first);
                    self.sym.put(dw);
                }
                dx_out
            }
            _ => unreachable!(),
        };
        self.sym.put(dy);
        dx_out
    }

    fn std_dense_dw(&mut self, rows: usize, k: usize, _n: usize, first: bool) {
        if first {
            // f32 AᵀB straight off the retained input — no transients
        } else if self.naive {
            let xs = self.sym.f32(rows * k);
            self.sym.put(xs);
        } else {
            let xh = self.sym.bits(rows, k);
            self.sym.put(xh);
        }
    }

    fn std_conv_dw(&mut self, b: usize, g: ConvGeom, cout: usize, first: bool) {
        let k = g.k();
        let rows = g.rows(b);
        let fused = !first && !self.naive;
        if fused {
            let xh = self.sym.bits(rows, k);
            let scratch = self.sym.f32(g.kside * g.kside * cout);
            self.sym.put(scratch);
            self.sym.put(xh);
        } else if first {
            // fused first-layer ∂W: one rows×cin tap panel on every
            // tier, no rows×k cols
            let panel = self.sym.f32(rows * g.cin);
            self.sym.put(panel);
        } else {
            let cols = self.sym.zeroed_f32(rows * k);
            let xs = self.sym.f32(g.in_len(b));
            self.sym.put(xs);
            self.sym.put(cols);
        }
    }

    fn drain_chunk_state(&mut self) {
        for v in std::mem::take(&mut self.acts) {
            self.sym.put(v);
        }
        let mu = std::mem::take(&mut self.bn_mu);
        let psi = std::mem::take(&mut self.bn_psi);
        for v in mu.into_iter().chain(psi) {
            self.sym.put(v);
        }
        for m in std::mem::take(&mut self.pool_masks_u32) {
            self.sym.put(m);
        }
    }

    // ---- proposed engine

    fn prop_fwd(&mut self, cur: SBuf, layer: &LayerPlan, retain: bool) -> SBuf {
        let (rows, k, n, first, conv) = self.geom(layer);
        let mut entry = SymRes::default();
        let out;
        if first {
            let w = self.sym.f32(k * n);
            out = match conv {
                None => self.sym.f32(rows * n),
                Some(_) if self.naive => self.sym.zeroed_f32(rows * n),
                Some(g) => {
                    // fused first conv: rows×cin tap panel, no
                    // rows×k cols
                    let o = self.sym.f32(rows * n);
                    let panel = self.sym.f32(rows * g.cin);
                    self.sym.put(panel);
                    o
                }
            };
            self.sym.put(w);
            if retain {
                entry.x_first = Some(cur);
            } else {
                self.sym.put(cur);
            }
        } else {
            let ste = self.sym.mask(cur.len);
            let xhat = self.sym.bits(rows, k);
            self.sym.put(cur);
            out = self.sym.f32(rows * n);
            if retain {
                entry.xhat = Some(xhat);
                entry.ste = Some(ste);
            } else {
                self.sym.put(xhat);
                self.sym.put(ste);
            }
        }
        // ℓ1 batch norm over packed signs
        let beta = self.sym.f32(n);
        let x_next = self.sym.f32(rows * n);
        let psi = self.sym.f32(n);
        let omega = self.sym.f32(n);
        let mu = self.sym.f32(n);
        let sign = self.sym.zeroed_bits(rows, n);
        self.sym.put(out);
        self.sym.put(beta);
        self.sym.put(mu);
        if retain {
            let pf = self.sym.f16(n);
            let of = self.sym.f16(n);
            entry.psi = Some(pf);
            entry.omega = Some(of);
            entry.bn_sign = Some(sign);
            self.res.push(entry);
        } else {
            self.sym.put(sign);
        }
        self.sym.put(psi);
        self.sym.put(omega);
        x_next
    }

    fn prop_bwd(&mut self, dnext: SBuf, wi: usize, layer: &LayerPlan) -> SBuf {
        let b = self.micro;
        let (rows, k, n, first, conv) = self.geom(layer);
        let dy = self.sym.f32(rows * n);
        let psi = self.sym.f32(n);
        let omega = self.sym.f32(n);
        let mv = self.sym.f32(n);
        let mvx = self.sym.f32(n);
        self.sym.put(psi);
        self.sym.put(omega);
        self.sym.put(mv);
        self.sym.put(mvx);
        self.sym.put(dnext);
        self.prop_accumulate_dw(wi, rows, k, n, first, conv);
        let dx = if first {
            SBuf::empty(PoolKind::F32)
        } else {
            match conv {
                None if self.naive => self.sym.zeroed_f32(rows * k),
                None => {
                    let wt_f = self.sym.f32(n * k);
                    let dx = self.sym.f32(rows * k);
                    self.sym.put(wt_f);
                    dx
                }
                Some(g) if self.naive => {
                    let dcols = self.sym.zeroed_f32(rows * k);
                    let dx = self.sym.zeroed_f32(g.in_len(b));
                    self.sym.put(dcols);
                    dx
                }
                Some(g) => {
                    let dx = self.sym.zeroed_f32(g.in_len(b));
                    let panel = self.sym.f32(rows * g.cin);
                    let wtap = self.sym.f32(n * g.cin);
                    self.sym.put(panel);
                    self.sym.put(wtap);
                    dx
                }
            }
        };
        self.sym.put(dy);
        dx
    }

    fn prop_accumulate_dw(
        &mut self,
        wi: usize,
        rows: usize,
        k: usize,
        n: usize,
        first: bool,
        conv: Option<ConvGeom>,
    ) {
        // first-conv ∂W streams tap panels (rows×cin) on the
        // accelerated tiers and reads patch elements in place on the
        // naive tier — the rows×k f32 im2col no longer exists
        let first_conv_cin = match (first, conv) {
            (true, Some(g)) => Some(g.cin),
            _ => None,
        };
        if !self.naive {
            if self.single {
                let dw = self.sym.f32(k * n);
                if let Some(cin) = first_conv_cin {
                    let panel = self.sym.f32(rows * cin);
                    self.sym.put(panel);
                }
                let bits = self.sym.bits(k, n);
                self.res[wi].dw_sign = Some(bits);
                self.sym.put(dw);
            } else {
                let scratch = self.sym.f32(k * n);
                if let Some(cin) = first_conv_cin {
                    let panel = self.sym.f32(rows * cin);
                    self.sym.put(panel);
                }
                self.sym.put(scratch);
            }
        } else {
            let acc = self.sym.f32(n);
            let bits = if self.single { Some(self.sym.zeroed_bits(k, n)) } else { None };
            self.sym.put(acc);
            if let Some(bits) = bits {
                self.res[wi].dw_sign = Some(bits);
            }
        }
    }

    fn drain_res(&mut self) {
        for r in std::mem::take(&mut self.res) {
            for opt in [r.xhat, r.x_first, r.ste, r.bn_sign, r.psi, r.omega, r.dw_sign] {
                if let Some(b) = opt {
                    self.sym.put(b);
                }
            }
        }
        for m in std::mem::take(&mut self.pool_masks_bits) {
            self.sym.put(m);
        }
        // general (non-2×2) pools retain u32 winner masks instead
        for m in std::mem::take(&mut self.pool_masks_u32) {
            self.sym.put(m);
        }
    }

    // ---- serving proposed forward (β and Ŵᵀ come off the snapshot:
    // no beta checkout, no STE mask)

    fn serve_prop_fwd(&mut self, cur: SBuf, layer: &LayerPlan) -> SBuf {
        let (rows, k, n, first, conv) = self.geom(layer);
        let out;
        if first {
            let w = self.sym.f32(k * n);
            out = match conv {
                None => self.sym.f32(rows * n),
                Some(_) if self.naive => self.sym.zeroed_f32(rows * n),
                Some(g) => {
                    // fused first conv (mirrors the trainer arm)
                    let o = self.sym.f32(rows * n);
                    let panel = self.sym.f32(rows * g.cin);
                    self.sym.put(panel);
                    o
                }
            };
            self.sym.put(w);
            self.sym.put(cur);
        } else {
            let xhat = self.sym.bits(rows, k);
            self.sym.put(cur);
            out = self.sym.f32(rows * n);
            self.sym.put(xhat);
        }
        let x_next = self.sym.f32(rows * n);
        let psi = self.sym.f32(n);
        let omega = self.sym.f32(n);
        let mu = self.sym.f32(n);
        let sign = self.sym.zeroed_bits(rows, n);
        self.sym.put(out);
        self.sym.put(psi);
        self.sym.put(omega);
        self.sym.put(mu);
        self.sym.put(sign);
        x_next
    }

    // ---- pass assemblies

    fn train_chunk(&mut self, fwd: &[OpInstr], bwd: &[OpInstr]) {
        let logits = self.forward(fwd, true);
        let dlogits = self.sym.f32(self.micro * self.classes);
        self.sym.put(logits);
        self.backward(bwd, dlogits);
        // end_chunk
        match self.mode {
            Mode::Std => self.drain_chunk_state(),
            Mode::Prop => {
                if !self.single {
                    self.drain_res();
                }
            }
            _ => unreachable!(),
        }
    }

    fn eval_chunk(&mut self, fwd: &[OpInstr]) {
        let logits = self.forward(fwd, false);
        let d = self.sym.f32(self.micro * self.classes);
        self.sym.put(logits);
        self.sym.put(d);
    }

    fn serve_infer(&mut self, fwd: &[OpInstr]) {
        let logits = self.forward(fwd, false);
        self.sym.put(logits);
    }

    fn serve_eval(&mut self, fwd: &[OpInstr]) {
        let logits = self.forward(fwd, false);
        let d = self.sym.f32(self.micro * self.classes);
        self.sym.put(logits);
        self.sym.put(d);
    }
}

// ----------------------------------------------------- coloring

struct RawPass {
    name: String,
    repeats: usize,
    raw: Vec<RawEv>,
    /// Index splitting chunk events from tail events.
    boundary: usize,
}

/// Greedy lifetime-overlap interval coloring.  Passes are processed in
/// `order` (largest first packs tightest); within a pass, each take
/// claims the tightest free slot that fits, else grows the widest free
/// slot, else opens a new slot.  One slot table is shared across all
/// passes — they never overlap in time, and the balance invariant
/// (every pass returns everything it takes) is enforced here.
fn color_passes(passes: &[RawPass], order: &[usize]) -> Result<(SlotTable, Vec<Arc<PassEvents>>)> {
    let mut caps: [Vec<usize>; POOLS] = Default::default();
    let mut colored: Vec<Option<Arc<PassEvents>>> = vec![None; passes.len()];
    for &pi in order {
        let p = &passes[pi];
        if !p.raw.is_empty() && p.repeats == 0 {
            bail!("pass '{}' has zero repeats", p.name);
        }
        if p.boundary < p.raw.len() && p.repeats != 1 {
            bail!("pass '{}' has a tail but repeats {}", p.name, p.repeats);
        }
        let mut free: [Vec<usize>; POOLS] = Default::default();
        for (fi, f) in free.iter_mut().enumerate() {
            f.extend(0..caps[fi].len());
        }
        let mut map: HashMap<usize, usize> = HashMap::new();
        let mut evs = Vec::with_capacity(p.raw.len());
        for ev in &p.raw {
            let pl = ev.pool.idx();
            if ev.take {
                // tightest fitting free slot, else grow the widest
                let mut fit: Option<(usize, usize)> = None; // (cap, pos)
                let mut widest: Option<(usize, usize)> = None;
                for (pos, &s) in free[pl].iter().enumerate() {
                    let c = caps[pl][s];
                    if c >= ev.len && fit.map_or(true, |(fc, fp)| (c, s) < (fc, free[pl][fp])) {
                        fit = Some((c, pos));
                    }
                    if widest.map_or(true, |(wc, wp)| c > wc || (c == wc && s < free[pl][wp])) {
                        widest = Some((c, pos));
                    }
                }
                let slot = if let Some((_, pos)) = fit {
                    free[pl].swap_remove(pos)
                } else if let Some((_, pos)) = widest {
                    let s = free[pl].swap_remove(pos);
                    caps[pl][s] = ev.len;
                    s
                } else {
                    caps[pl].push(ev.len);
                    caps[pl].len() - 1
                };
                if map.insert(ev.id, slot).is_some() {
                    bail!("pass '{}': buffer id {} taken twice", p.name, ev.id);
                }
                evs.push(BufEvent::Take { pool: ev.pool, slot, len: ev.len, init: ev.init });
            } else {
                let slot = map
                    .remove(&ev.id)
                    .ok_or_else(|| anyhow::anyhow!("pass '{}': put without take", p.name))?;
                free[pl].push(slot);
                evs.push(BufEvent::Put { pool: ev.pool, slot });
            }
        }
        if !map.is_empty() {
            bail!("pass '{}' leaks {} buffers past its end", p.name, map.len());
        }
        let tail = evs.split_off(p.boundary);
        colored[pi] = Some(Arc::new(PassEvents {
            name: p.name.clone(),
            repeats: p.repeats,
            events: evs,
            tail,
        }));
    }
    let passes = colored
        .into_iter()
        .map(|c| c.expect("order must cover every pass"))
        .collect();
    Ok((SlotTable { caps }, passes))
}

/// What the old per-buffer best-fit free list would have pooled after
/// running all passes once, in `order` — the uncolored baseline.
/// Best-fit: smallest pooled capacity ≥ len, a miss allocates exactly
/// len.  Replays the retired `StepArena` free-list policy.
fn bestfit_bytes(passes: &[RawPass], order: &[usize]) -> usize {
    let mut pools: [Vec<usize>; POOLS] = Default::default(); // sorted caps
    let mut out: HashMap<usize, usize> = HashMap::new();
    for &pi in order {
        for ev in &passes[pi].raw {
            let pl = ev.pool.idx();
            if ev.take {
                let idx = pools[pl].partition_point(|&c| c < ev.len);
                let cap =
                    if idx < pools[pl].len() { pools[pl].remove(idx) } else { ev.len };
                out.insert(ev.id, cap);
            } else if let Some(cap) = out.remove(&ev.id) {
                let idx = pools[pl].partition_point(|&c| c < cap);
                pools[pl].insert(idx, cap);
            }
        }
    }
    PoolKind::ALL
        .iter()
        .map(|&p| pools[p.idx()].iter().sum::<usize>() * p.elem_bytes())
        .sum()
}

// --------------------------------------------------- compilation

fn parse_algo(algo: &str) -> Result<bool> {
    match algo {
        "standard" => Ok(false),
        "proposed" => Ok(true),
        other => bail!("unknown algo '{other}' (standard|proposed)"),
    }
}

/// Compile a trainer schedule: a `train` pass (one chunk, replayed
/// `chunks` times, plus the proposed single-chunk residual-drain tail)
/// and an `eval` pass.
pub fn compile_step(
    plan: &Plan,
    algo: &str,
    naive: bool,
    micro: usize,
    chunks: usize,
) -> Result<StepSchedule> {
    let prop = parse_algo(algo)?;
    if micro == 0 || chunks == 0 {
        bail!("microbatch and chunk count must be positive");
    }
    let (fwd, bwd) = lower_ops(plan);
    let mode = if prop { Mode::Prop } else { Mode::Std };
    let single = chunks == 1;

    let mut eng = SymEngine::new(mode, naive, micro, single, plan.input_elems, plan.classes);
    eng.train_chunk(&fwd, &bwd);
    let boundary = eng.sym.raw.len();
    if prop && single {
        eng.drain_res();
    }
    let train =
        RawPass { name: "train".into(), repeats: chunks, raw: eng.sym.raw, boundary };

    let mut eng = SymEngine::new(mode, naive, micro, single, plan.input_elems, plan.classes);
    eng.eval_chunk(&fwd);
    let boundary = eng.sym.raw.len();
    let eval = RawPass { name: "eval".into(), repeats: chunks, raw: eng.sym.raw, boundary };

    let raw = [train, eval];
    let order = [0usize, 1];
    let (slots, passes) = color_passes(&raw, &order)?;
    let uncolored_bytes = bestfit_bytes(&raw, &order);
    Ok(StepSchedule {
        kind: ScheduleKind::Step,
        model: plan.name.clone(),
        algo: algo.into(),
        naive,
        micro,
        chunks,
        input_elems: plan.input_elems,
        classes: plan.classes,
        fwd_ops: fwd,
        bwd_ops: bwd,
        slots,
        passes,
        uncolored_bytes,
    })
}

/// Compile a serving schedule: an infer pass and an eval pass per
/// batch size `1..=max_batch`.  Colored largest-batch first, which is
/// also the engine's warmup order.
pub fn compile_serve(
    plan: &Plan,
    algo: &str,
    naive: bool,
    max_batch: usize,
) -> Result<StepSchedule> {
    let prop = parse_algo(algo)?;
    if max_batch == 0 {
        bail!("max_batch must be positive");
    }
    let (fwd, _) = lower_ops(plan);
    let mode = if prop { Mode::ServeProp } else { Mode::ServeStd };
    let mut raw = Vec::with_capacity(2 * max_batch);
    for b in 1..=max_batch {
        let mut eng = SymEngine::new(mode, naive, b, true, plan.input_elems, plan.classes);
        eng.serve_infer(&fwd);
        let boundary = eng.sym.raw.len();
        raw.push(RawPass { name: format!("infer{b}"), repeats: 1, raw: eng.sym.raw, boundary });
    }
    for b in 1..=max_batch {
        let mut eng = SymEngine::new(mode, naive, b, true, plan.input_elems, plan.classes);
        eng.serve_eval(&fwd);
        let boundary = eng.sym.raw.len();
        raw.push(RawPass { name: format!("eval{b}"), repeats: 1, raw: eng.sym.raw, boundary });
    }
    // descending batch: infer_b then eval_b
    let mut order = Vec::with_capacity(2 * max_batch);
    for b in (1..=max_batch).rev() {
        order.push(b - 1);
        order.push(max_batch + b - 1);
    }
    let (slots, passes) = color_passes(&raw, &order)?;
    let uncolored_bytes = bestfit_bytes(&raw, &order);
    Ok(StepSchedule {
        kind: ScheduleKind::Serve,
        model: plan.name.clone(),
        algo: algo.into(),
        naive,
        micro: max_batch,
        chunks: 1,
        input_elems: plan.input_elems,
        classes: plan.classes,
        fwd_ops: fwd,
        bwd_ops: Vec::new(),
        slots,
        passes,
        uncolored_bytes,
    })
}

// ------------------------------------------------------------ JSON

fn layer_to_json(layer: &LayerPlan) -> Json {
    let mut j = Json::obj();
    match *layer {
        LayerPlan::Dense { k, n, first } => {
            j.set("t", Json::from("dense"))
                .set("k", Json::from(k))
                .set("n", Json::from(n))
                .set("first", Json::from(first));
        }
        LayerPlan::Conv { g, cout, first } => {
            j.set("t", Json::from("conv"))
                .set("cout", Json::from(cout))
                .set("first", Json::from(first))
                .set("h", Json::from(g.h))
                .set("w", Json::from(g.w))
                .set("cin", Json::from(g.cin))
                .set("kside", Json::from(g.kside))
                .set("stride", Json::from(g.stride))
                .set("pad_h", Json::from(g.pad_h))
                .set("pad_w", Json::from(g.pad_w))
                .set("oh", Json::from(g.oh))
                .set("ow", Json::from(g.ow));
        }
        _ => unreachable!("only matmul layers are embedded in ops"),
    }
    j
}

fn layer_from_json(j: &Json) -> Result<LayerPlan> {
    let first = j.req("first")?.as_bool()?;
    Ok(match j.req("t")?.as_str()? {
        "dense" => LayerPlan::Dense {
            k: j.req("k")?.as_usize()?,
            n: j.req("n")?.as_usize()?,
            first,
        },
        "conv" => LayerPlan::Conv {
            g: ConvGeom {
                h: j.req("h")?.as_usize()?,
                w: j.req("w")?.as_usize()?,
                cin: j.req("cin")?.as_usize()?,
                kside: j.req("kside")?.as_usize()?,
                stride: j.req("stride")?.as_usize()?,
                pad_h: j.req("pad_h")?.as_usize()?,
                pad_w: j.req("pad_w")?.as_usize()?,
                oh: j.req("oh")?.as_usize()?,
                ow: j.req("ow")?.as_usize()?,
            },
            cout: j.req("cout")?.as_usize()?,
            first,
        },
        other => bail!("unknown layer type '{other}'"),
    })
}

fn op_to_json(op: &OpInstr) -> Json {
    let mut j = Json::obj();
    match op {
        OpInstr::Matmul { wi, layer } => {
            j.set("op", Json::from("matmul"))
                .set("wi", Json::from(*wi))
                .set("layer", layer_to_json(layer));
        }
        OpInstr::MaxPool { h, w, c, kside, stride } => {
            j.set("op", Json::from("maxpool"))
                .set("h", Json::from(*h))
                .set("w", Json::from(*w))
                .set("c", Json::from(*c));
            // emitted only for non-default geometry so committed 2×2
            // stride-2 schedule dumps stay byte-identical
            if (*kside, *stride) != (2, 2) {
                j.set("kside", Json::from(*kside)).set("stride", Json::from(*stride));
            }
        }
        OpInstr::GlobalPool { h, w, c } => {
            j.set("op", Json::from("gpool"))
                .set("h", Json::from(*h))
                .set("w", Json::from(*w))
                .set("c", Json::from(*c));
        }
        OpInstr::SkipSave => {
            j.set("op", Json::from("skip_save"));
        }
        OpInstr::SkipClose { skip } => {
            j.set("op", Json::from("skip_close"))
                .set("h", Json::from(skip.h))
                .set("w", Json::from(skip.w))
                .set("c", Json::from(skip.c))
                .set("oh", Json::from(skip.oh))
                .set("ow", Json::from(skip.ow))
                .set("co", Json::from(skip.co))
                .set("stride", Json::from(skip.stride));
        }
    }
    j
}

fn op_from_json(j: &Json) -> Result<OpInstr> {
    Ok(match j.req("op")?.as_str()? {
        "matmul" => OpInstr::Matmul {
            wi: j.req("wi")?.as_usize()?,
            layer: layer_from_json(j.req("layer")?)?,
        },
        "maxpool" => OpInstr::MaxPool {
            h: j.req("h")?.as_usize()?,
            w: j.req("w")?.as_usize()?,
            c: j.req("c")?.as_usize()?,
            kside: j.get("kside").map(Json::as_usize).transpose()?.unwrap_or(2),
            stride: j.get("stride").map(Json::as_usize).transpose()?.unwrap_or(2),
        },
        "gpool" => OpInstr::GlobalPool {
            h: j.req("h")?.as_usize()?,
            w: j.req("w")?.as_usize()?,
            c: j.req("c")?.as_usize()?,
        },
        "skip_save" => OpInstr::SkipSave,
        "skip_close" => OpInstr::SkipClose {
            skip: SkipGeom {
                h: j.req("h")?.as_usize()?,
                w: j.req("w")?.as_usize()?,
                c: j.req("c")?.as_usize()?,
                oh: j.req("oh")?.as_usize()?,
                ow: j.req("ow")?.as_usize()?,
                co: j.req("co")?.as_usize()?,
                stride: j.req("stride")?.as_usize()?,
            },
        },
        other => bail!("unknown op '{other}'"),
    })
}

fn event_to_json(ev: &BufEvent) -> Json {
    Json::Arr(match *ev {
        BufEvent::Take { pool, slot, len, init } => vec![
            Json::from("t"),
            Json::from(pool.name()),
            Json::from(slot),
            Json::from(len),
            Json::from(init.code()),
        ],
        BufEvent::Put { pool, slot } => {
            vec![Json::from("p"), Json::from(pool.name()), Json::from(slot)]
        }
    })
}

fn event_from_json(j: &Json) -> Result<BufEvent> {
    let a = j.as_arr()?;
    match a.first().map(Json::as_str).transpose()? {
        Some("t") if a.len() == 5 => Ok(BufEvent::Take {
            pool: PoolKind::parse(a[1].as_str()?)?,
            slot: a[2].as_usize()?,
            len: a[3].as_usize()?,
            init: TakeInit::parse(a[4].as_str()?)?,
        }),
        Some("p") if a.len() == 3 => Ok(BufEvent::Put {
            pool: PoolKind::parse(a[1].as_str()?)?,
            slot: a[2].as_usize()?,
        }),
        _ => bail!("malformed event {j}"),
    }
}

impl StepSchedule {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("version", Json::from(1usize))
            .set(
                "kind",
                Json::from(match self.kind {
                    ScheduleKind::Step => "step",
                    ScheduleKind::Serve => "serve",
                }),
            )
            .set("model", Json::from(self.model.as_str()))
            .set("algo", Json::from(self.algo.as_str()))
            .set("naive", Json::from(self.naive))
            .set("micro", Json::from(self.micro))
            .set("chunks", Json::from(self.chunks))
            .set("input_elems", Json::from(self.input_elems))
            .set("classes", Json::from(self.classes))
            .set("colored_bytes", Json::from(self.arena_bytes()))
            .set("uncolored_bytes", Json::from(self.uncolored_bytes))
            .set("fwd_ops", Json::Arr(self.fwd_ops.iter().map(op_to_json).collect()))
            .set("bwd_ops", Json::Arr(self.bwd_ops.iter().map(op_to_json).collect()));
        let mut slots = Json::obj();
        for p in PoolKind::ALL {
            slots.set(
                p.name(),
                Json::Arr(self.slots.caps[p.idx()].iter().map(|&c| Json::from(c)).collect()),
            );
        }
        j.set("slots", slots);
        let passes = self
            .passes
            .iter()
            .map(|p| {
                let mut pj = Json::obj();
                pj.set("name", Json::from(p.name.as_str()))
                    .set("repeats", Json::from(p.repeats))
                    .set("events", Json::Arr(p.events.iter().map(event_to_json).collect()))
                    .set("tail", Json::Arr(p.tail.iter().map(event_to_json).collect()));
                pj
            })
            .collect();
        j.set("passes", Json::Arr(passes));
        j
    }

    pub fn from_json(j: &Json) -> Result<StepSchedule> {
        let version = j.req("version")?.as_usize()?;
        if version != 1 {
            bail!("unsupported schedule version {version}");
        }
        let kind = match j.req("kind")?.as_str()? {
            "step" => ScheduleKind::Step,
            "serve" => ScheduleKind::Serve,
            other => bail!("unknown schedule kind '{other}'"),
        };
        let mut caps: [Vec<usize>; POOLS] = Default::default();
        let slots = j.req("slots")?;
        for p in PoolKind::ALL {
            caps[p.idx()] = slots
                .req(p.name())?
                .as_arr()?
                .iter()
                .map(Json::as_usize)
                .collect::<Result<_>>()?;
        }
        let parse_ops = |key: &str| -> Result<Vec<OpInstr>> {
            j.req(key)?.as_arr()?.iter().map(op_from_json).collect()
        };
        let passes = j
            .req("passes")?
            .as_arr()?
            .iter()
            .map(|pj| {
                Ok(Arc::new(PassEvents {
                    name: pj.req("name")?.as_str()?.to_string(),
                    repeats: pj.req("repeats")?.as_usize()?,
                    events: pj
                        .req("events")?
                        .as_arr()?
                        .iter()
                        .map(event_from_json)
                        .collect::<Result<_>>()?,
                    tail: pj
                        .req("tail")?
                        .as_arr()?
                        .iter()
                        .map(event_from_json)
                        .collect::<Result<_>>()?,
                }))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(StepSchedule {
            kind,
            model: j.req("model")?.as_str()?.to_string(),
            algo: j.req("algo")?.as_str()?.to_string(),
            naive: j.req("naive")?.as_bool()?,
            micro: j.req("micro")?.as_usize()?,
            chunks: j.req("chunks")?.as_usize()?,
            input_elems: j.req("input_elems")?.as_usize()?,
            classes: j.req("classes")?.as_usize()?,
            fwd_ops: parse_ops("fwd_ops")?,
            bwd_ops: parse_ops("bwd_ops")?,
            slots: SlotTable { caps },
            passes,
            uncolored_bytes: j.req("uncolored_bytes")?.as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    fn plan_for(model: &str) -> Plan {
        Plan::from_graph(&lower(&get(model).unwrap()).unwrap()).unwrap()
    }

    #[test]
    fn lowering_flattens_and_bakes_weight_indices() {
        let plan = plan_for("binarynet_mini");
        let (fwd, bwd) = lower_ops(&plan);
        // conv,conv,pool,conv,conv,pool,flatten,fc,fc,fc → 9 ops
        assert_eq!(fwd.len(), 9);
        assert!(!fwd.iter().any(|o| matches!(o, OpInstr::SkipSave)));
        let wis: Vec<usize> = fwd
            .iter()
            .filter_map(|o| match o {
                OpInstr::Matmul { wi, .. } => Some(*wi),
                _ => None,
            })
            .collect();
        assert_eq!(wis, vec![0, 1, 2, 3, 4, 5, 6]);
        assert_eq!(bwd.len(), fwd.len());
        assert!(matches!(bwd[0], OpInstr::Matmul { wi: 6, .. }));
    }

    #[test]
    fn every_zoo_schedule_compiles_balanced_and_colored() {
        for model in crate::models::names() {
            let plan = plan_for(model);
            for algo in ["standard", "proposed"] {
                for naive in [false, true] {
                    for (micro, chunks) in [(2usize, 1usize), (1, 2)] {
                        let s = compile_step(&plan, algo, naive, micro, chunks)
                            .unwrap_or_else(|e| panic!("{model}/{algo}: {e}"));
                        assert!(s.arena_bytes() > 0, "{model}/{algo}");
                        assert!(
                            s.arena_bytes() <= s.uncolored_bytes,
                            "{model}/{algo} naive={naive} micro={micro}: colored {} > \
                             uncolored {}",
                            s.arena_bytes(),
                            s.uncolored_bytes
                        );
                    }
                }
                let s = compile_serve(&plan, algo, false, 3).unwrap();
                assert_eq!(s.passes.len(), 6, "{model}/{algo}");
                assert!(s.arena_bytes() <= s.uncolored_bytes, "{model}/{algo} serve");
            }
        }
    }

    #[test]
    fn coloring_never_overlaps_live_ranges() {
        for model in ["cnv_mini", "resnete_mini", "mlp_mini"] {
            let plan = plan_for(model);
            for algo in ["standard", "proposed"] {
                let s = compile_step(&plan, algo, false, 2, 2).unwrap();
                for p in &s.passes {
                    let mut live: [Vec<bool>; POOLS] =
                        std::array::from_fn(|i| vec![false; s.slots.caps[i].len()]);
                    for ev in p.events.iter().chain(&p.tail) {
                        match *ev {
                            BufEvent::Take { pool, slot, len, .. } => {
                                let pl = pool.idx();
                                assert!(
                                    !live[pl][slot],
                                    "{model}/{algo}/{}: slot {slot} double-taken",
                                    p.name
                                );
                                assert!(len <= s.slots.caps[pl][slot]);
                                live[pl][slot] = true;
                            }
                            BufEvent::Put { pool, slot } => {
                                assert!(live[pool.idx()][slot]);
                                live[pool.idx()][slot] = false;
                            }
                        }
                    }
                    assert!(
                        live.iter().all(|l| l.iter().all(|&x| !x)),
                        "{model}/{algo}/{}: pass leaks slots",
                        p.name
                    );
                }
            }
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let plan = plan_for("cnv_mini");
        for algo in ["standard", "proposed"] {
            let s = compile_step(&plan, algo, false, 2, 2).unwrap();
            let text = s.to_json().to_string_pretty();
            let back = StepSchedule::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(s, back, "{algo}");
            let sv = compile_serve(&plan, algo, true, 2).unwrap();
            let back =
                StepSchedule::from_json(&Json::parse(&sv.to_json().to_string()).unwrap())
                    .unwrap();
            assert_eq!(sv, back, "{algo} serve");
        }
    }

    #[test]
    fn proposed_single_chunk_has_residual_tail() {
        let plan = plan_for("mlp_mini");
        let s = compile_step(&plan, "proposed", false, 4, 1).unwrap();
        assert!(!s.train_pass().tail.is_empty());
        assert!(s.train_pass().tail.iter().all(|e| matches!(e, BufEvent::Put { .. })));
        // multi-chunk: the drain happens per chunk, no tail
        let s = compile_step(&plan, "proposed", false, 2, 2).unwrap();
        assert!(s.train_pass().tail.is_empty());
    }
}
