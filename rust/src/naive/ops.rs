//! Shared layer-graph execution core for the two naive engines.
//!
//! Both trainers walk the same [`super::plan::Plan`]; what differs is
//! *per-matmul-layer* behaviour (what is retained and at which
//! precision, which BN variant runs, how ∂W is stored) and the
//! inter-layer gradient carrier (f32 for the standard engine, f16 for
//! the proposed one).  Everything else — the layer-graph control
//! flow, max-pool routing, global average pooling, residual skip
//! handling, and the **microbatch chunk loop** (forward + backward
//! per microbatch, gradients accumulating across chunks before one
//! optimizer step) — is written once here, over the [`EngineOps`]
//! trait.
//!
//! ## Arena discipline
//!
//! Every `Vec<f32>` crossing the [`EngineOps`] boundary is a
//! [`StepCtx`] arena checkout.  The receiver of an owned buffer must
//! retain it (per-chunk residual state), recycle it
//! (`ctx().arena.put_f32`), or return it; nothing on the step path
//! may `Vec::new` + drop.  After one warmup step the arena pool is at
//! fixed point and steady-state steps perform zero heap allocations
//! (asserted by rust/tests/memtrack_step.rs via
//! `memtrack::alloc_count`).
//!
//! Residual skips are f32 in both engines: the high-precision skip
//! path is the accuracy enhancement the paper incorporates (Sec. 2),
//! and `memmodel` prices it as an f32 transient
//! (`Graph::residual_skip_elems`).

use anyhow::{bail, Result};

use super::arena::StepCtx;
use super::plan::{LayerPlan, SkipGeom};
use super::schedule::{OpInstr, StepSchedule};
use super::softmax_xent_grad;
use crate::bitops::simd;

/// Engine-specific per-layer ops the shared driver composes.
///
/// `Grad` is the inter-layer gradient carrier (`Vec<f32>` — identity
/// conversions — for the standard engine; `F16Vec` for the proposed
/// engine, so gradients crossing layer boundaries really are held in
/// f16: the driver converts at each boundary and a f16→f32→f16
/// round-trip is lossless).  Conversions take `&mut self` so the
/// carriers themselves recycle through the engine's arena.
pub(crate) trait EngineOps {
    type Grad;

    /// Execution batch of one chunk (the microbatch — every per-step
    /// buffer is sized by this, not the logical batch).
    fn micro(&self) -> usize;

    /// The engine's step context: arena pool + driver skip stacks.
    fn ctx(&mut self) -> &mut StepCtx;

    fn grad_to_f32(&mut self, g: Self::Grad) -> Vec<f32>;
    fn grad_from_f32(&mut self, v: Vec<f32>) -> Self::Grad;
    /// Return a carrier's storage to the arena.
    fn recycle_grad(&mut self, g: Self::Grad);

    /// One matmul layer (dense or conv) forward + batch norm;
    /// consumes `cur` (retaining or recycling it), returns the BN
    /// output; retains whatever this engine's backward needs when
    /// `retain`.
    fn matmul_forward(
        &mut self,
        cur: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        retain: bool,
    ) -> Result<Vec<f32>>;

    /// One matmul layer backward (BN backward, ∂W/∂β *accumulation*
    /// into the step's gradient accumulators, ∂X); consumes the f32
    /// gradient w.r.t. this layer's BN output, returns the gradient
    /// w.r.t. its input (empty for the first layer).  Optimizer
    /// updates are deferred to the engine's update phase after the
    /// last chunk.
    fn matmul_backward(&mut self, dnext: Vec<f32>, wi: usize, layer: &LayerPlan)
        -> Result<Vec<f32>>;

    /// `kside`×`kside` stride-`stride` max-pool forward; the engine
    /// stores its own mask format (pushed in layer order — the
    /// backward pops in reverse).
    #[allow(clippy::too_many_arguments)]
    fn pool_forward(
        &mut self,
        cur: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
        retain: bool,
    ) -> Vec<f32>;
    fn pool_backward(
        &mut self,
        dnext: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
    ) -> Vec<f32>;

    /// Drain this chunk's retained state back into the arena (called
    /// after each chunk's backward; single-chunk engines that keep
    /// update inputs in retained state drain after the update phase
    /// instead).
    fn end_chunk(&mut self);
}

/// Forward through a compiled op list ([`StepSchedule::fwd_ops`] —
/// `Flatten` already eliminated, weight indices baked in); returns
/// logits (an arena checkout).  `retain` disables residual storage
/// for eval (skip buffers are still consumed — they are part of the
/// function value, not of the retained state).
pub(crate) fn forward_plan<E: EngineOps>(
    e: &mut E,
    ops: &[OpInstr],
    x: &[f32],
    retain: bool,
) -> Result<Vec<f32>> {
    let b = e.micro();
    let mut cur = e.ctx().arena.take_copy_f32(x);
    for op in ops {
        match op {
            OpInstr::Matmul { wi, layer } => {
                cur = e.matmul_forward(cur, *wi, layer, retain)?;
            }
            OpInstr::MaxPool { h, w, c, kside, stride } => {
                cur = e.pool_forward(cur, *h, *w, *c, *kside, *stride, retain);
            }
            OpInstr::GlobalPool { h, w, c } => {
                let ctx = e.ctx();
                let mut out = ctx.arena.take_f32(b * c);
                global_pool_forward_into(&cur, b, *h, *w, *c, &mut out);
                ctx.arena.put_f32(std::mem::replace(&mut cur, out));
            }
            OpInstr::SkipSave => {
                let ctx = e.ctx();
                let s = ctx.arena.take_copy_f32(&cur);
                ctx.skips.push(s);
            }
            OpInstr::SkipClose { skip } => {
                let ctx = e.ctx();
                let s = ctx.skips.pop().ok_or_else(|| {
                    anyhow::anyhow!("residual add without a saved skip (schedule bug)")
                })?;
                skip_add(&mut cur, &s, b, skip);
                ctx.arena.put_f32(s);
            }
        }
    }
    if !e.ctx().skips.is_empty() {
        bail!("unconsumed residual skip (schedule bug)");
    }
    Ok(cur)
}

/// Backward through a compiled op list ([`StepSchedule::bwd_ops`] —
/// already in reverse graph order, weight indices baked in),
/// consuming ∂logits (an arena checkout).  Produces gradient
/// *accumulations* only; the engine's update phase applies them after
/// the last chunk.
pub(crate) fn backward_plan<E: EngineOps>(
    e: &mut E,
    ops: &[OpInstr],
    dlogits: Vec<f32>,
) -> Result<()> {
    let b = e.micro();
    let mut dcur = e.grad_from_f32(dlogits);
    // gradients of pending skip branches: recorded at the block
    // output (SkipClose, seen first in reverse order), merged into
    // the main gradient at the block input (SkipSave)
    for op in ops {
        match op {
            OpInstr::Matmul { wi, layer } => {
                let d = e.grad_to_f32(dcur);
                let dx = e.matmul_backward(d, *wi, layer)?;
                dcur = e.grad_from_f32(dx);
            }
            OpInstr::MaxPool { h, w, c, kside, stride } => {
                let d = e.grad_to_f32(dcur);
                let dx = e.pool_backward(d, *h, *w, *c, *kside, *stride);
                dcur = e.grad_from_f32(dx);
            }
            OpInstr::GlobalPool { h, w, c } => {
                let d = e.grad_to_f32(dcur);
                let ctx = e.ctx();
                let mut dx = ctx.arena.take_f32(b * h * w * c);
                global_pool_backward_into(&d, b, *h, *w, *c, &mut dx);
                ctx.arena.put_f32(d);
                dcur = e.grad_from_f32(dx);
            }
            OpInstr::SkipClose { skip } => {
                // d(out)/d(skip) is the downsample adjoint; the block
                // path receives the gradient unchanged (the add is an
                // identity towards the closing conv's BN output)
                let d = e.grad_to_f32(dcur);
                let ctx = e.ctx();
                let mut sg = ctx.arena.take_zeroed_f32(b * skip.h * skip.w * skip.c);
                skip_grad_into(&d, b, skip, &mut sg);
                ctx.skip_grads.push(sg);
                dcur = e.grad_from_f32(d);
            }
            OpInstr::SkipSave => {
                let mut d = e.grad_to_f32(dcur);
                let ctx = e.ctx();
                let g = ctx.skip_grads.pop().ok_or_else(|| {
                    anyhow::anyhow!("residual save without a recorded skip grad (schedule bug)")
                })?;
                simd::add_assign_f32(&mut d, &g);
                ctx.arena.put_f32(g);
                dcur = e.grad_from_f32(d);
            }
        }
    }
    e.recycle_grad(dcur);
    if !e.ctx().skip_grads.is_empty() {
        bail!("unconsumed residual skip grad (schedule bug)");
    }
    Ok(())
}

/// The microbatched step loop shared by both engines: split the
/// logical batch into `chunks` microbatches, run forward + backward
/// per chunk (per-chunk BN statistics — ghost batch norm; gradients
/// are scaled by `1/chunks` so the accumulated ∂W/∂β equal the
/// *mean* over the logical batch), and return the averaged
/// (loss, accuracy).  The engine applies its deferred optimizer
/// update afterwards.
pub(crate) fn run_train_chunks<E: EngineOps>(
    e: &mut E,
    sched: &StepSchedule,
    x: &[f32],
    labels: &[usize],
) -> Result<(f32, f32)> {
    let m = e.micro();
    let (classes, input_elems, chunks) = (sched.classes, sched.input_elems, sched.chunks);
    let mut loss_sum = 0.0f32;
    let mut acc_sum = 0.0f32;
    for ci in 0..chunks {
        let xs = &x[ci * m * input_elems..(ci + 1) * m * input_elems];
        let ys = &labels[ci * m..(ci + 1) * m];
        let logits = forward_plan(e, &sched.fwd_ops, xs, true)?;
        let ctx = e.ctx();
        let mut dlogits = ctx.arena.take_f32(m * classes);
        let (loss, acc) = softmax_xent_grad(&logits, ys, classes, &mut dlogits);
        ctx.arena.put_f32(logits);
        if chunks > 1 {
            // softmax divided by the chunk rows; rescale so the sum
            // over chunks is the logical-batch mean
            let inv = 1.0 / chunks as f32;
            for v in dlogits.iter_mut() {
                *v *= inv;
            }
        }
        backward_plan(e, &sched.bwd_ops, dlogits)?;
        e.end_chunk();
        loss_sum += loss;
        acc_sum += acc;
    }
    Ok((loss_sum / chunks as f32, acc_sum / chunks as f32))
}

/// Chunked forward-only evaluation (mirrors the microbatch split so
/// eval buffers stay microbatch-sized too).
pub(crate) fn run_eval_chunks<E: EngineOps>(
    e: &mut E,
    sched: &StepSchedule,
    x: &[f32],
    labels: &[usize],
) -> Result<(f32, f32)> {
    let m = e.micro();
    let (classes, input_elems, chunks) = (sched.classes, sched.input_elems, sched.chunks);
    let mut loss_sum = 0.0f32;
    let mut acc_sum = 0.0f32;
    for ci in 0..chunks {
        let xs = &x[ci * m * input_elems..(ci + 1) * m * input_elems];
        let ys = &labels[ci * m..(ci + 1) * m];
        let logits = forward_plan(e, &sched.fwd_ops, xs, false)?;
        let ctx = e.ctx();
        let mut d = ctx.arena.take_f32(m * classes);
        let (loss, acc) = softmax_xent_grad(&logits, ys, classes, &mut d);
        ctx.arena.put_f32(logits);
        ctx.arena.put_f32(d);
        loss_sum += loss;
        acc_sum += acc;
    }
    Ok((loss_sum / chunks as f32, acc_sum / chunks as f32))
}

// ------------------------------------------------ engine-independent ops

/// Global average pool: NHWC (b, h, w, c) → (b, c).
/// (Allocating test convenience; the driver uses the `_into` form.)
#[cfg(test)]
pub(crate) fn global_pool_forward(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * c];
    global_pool_forward_into(x, b, h, w, c, &mut out);
    out
}

/// [`global_pool_forward`] into a caller-owned buffer (re-zeroed
/// here, recycled dirty storage fine).
pub(crate) fn global_pool_forward_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
) {
    let hw = h * w;
    debug_assert_eq!(x.len(), b * hw * c);
    debug_assert_eq!(out.len(), b * c);
    let inv = 1.0 / hw as f32;
    out.fill(0.0);
    for bi in 0..b {
        let orow = &mut out[bi * c..(bi + 1) * c];
        for p in 0..hw {
            let xrow = &x[(bi * hw + p) * c..][..c];
            simd::add_assign_f32(orow, xrow);
        }
        for v in orow.iter_mut() {
            *v *= inv;
        }
    }
}

/// Global average pool backward: every position receives ∂y/(h·w).
#[cfg(test)]
pub(crate) fn global_pool_backward(
    dy: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<f32> {
    let mut dx = vec![0.0f32; b * h * w * c];
    global_pool_backward_into(dy, b, h, w, c, &mut dx);
    dx
}

/// [`global_pool_backward`] into a caller-owned buffer (every cell
/// written, recycled dirty storage fine).
pub(crate) fn global_pool_backward_into(
    dy: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    dx: &mut [f32],
) {
    let hw = h * w;
    debug_assert_eq!(dy.len(), b * c);
    debug_assert_eq!(dx.len(), b * hw * c);
    let inv = 1.0 / hw as f32;
    for bi in 0..b {
        let src = &dy[bi * c..(bi + 1) * c];
        for p in 0..hw {
            let row = &mut dx[(bi * hw + p) * c..][..c];
            for (o, &v) in row.iter_mut().zip(src) {
                *o = v * inv;
            }
        }
    }
}

/// Add the downsampled skip into the block-output map in place:
/// `cur[bi, oy, ox, co] += skip[bi, oy·stride, ox·stride, co mod c]`
/// — strided 1×1 average pool (pure subsample) + channel duplication.
pub(crate) fn skip_add(cur: &mut [f32], skip: &[f32], b: usize, g: &SkipGeom) {
    debug_assert_eq!(cur.len(), b * g.oh * g.ow * g.co);
    debug_assert_eq!(skip.len(), b * g.h * g.w * g.c);
    if g.stride == 1 && g.c == g.co {
        simd::add_assign_f32(cur, skip);
        return;
    }
    let s = g.stride;
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let src = ((bi * g.h + oy * s) * g.w + ox * s) * g.c;
                let dst = ((bi * g.oh + oy) * g.ow + ox) * g.co;
                if g.c == g.co {
                    simd::add_assign_f32(&mut cur[dst..dst + g.co], &skip[src..src + g.c]);
                } else {
                    for co in 0..g.co {
                        cur[dst + co] += skip[src + co % g.c];
                    }
                }
            }
        }
    }
}

/// Adjoint of the downsample shortcut: gradient w.r.t. the saved
/// skip.  Sampled positions accumulate the sums of their duplicated
/// channels; unsampled positions (stride > 1) get zero.
#[cfg(test)]
pub(crate) fn skip_grad(d: &[f32], b: usize, g: &SkipGeom) -> Vec<f32> {
    let mut ds = vec![0.0f32; b * g.h * g.w * g.c];
    skip_grad_into(d, b, g, &mut ds);
    ds
}

/// [`skip_grad`] into a caller-owned buffer, which must be **zeroed**
/// (strided geometries scatter-add; the identity fast path copies).
pub(crate) fn skip_grad_into(d: &[f32], b: usize, g: &SkipGeom, ds: &mut [f32]) {
    debug_assert_eq!(d.len(), b * g.oh * g.ow * g.co);
    debug_assert_eq!(ds.len(), b * g.h * g.w * g.c);
    if g.stride == 1 && g.c == g.co {
        ds.copy_from_slice(d);
        return;
    }
    let s = g.stride;
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let dst = ((bi * g.h + oy * s) * g.w + ox * s) * g.c;
                let src = ((bi * g.oh + oy) * g.ow + ox) * g.co;
                if g.c == g.co {
                    simd::add_assign_f32(&mut ds[dst..dst + g.c], &d[src..src + g.co]);
                } else {
                    for co in 0..g.co {
                        ds[dst + co % g.c] += d[src + co];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn global_pool_forward_means() {
        let (b, h, w, c) = (2, 2, 3, 2);
        let mut g = Pcg32::new(1);
        let x = g.normal_vec(b * h * w * c);
        let out = global_pool_forward(&x, b, h, w, c);
        for bi in 0..b {
            for ch in 0..c {
                let want: f32 = (0..h * w)
                    .map(|p| x[(bi * h * w + p) * c + ch])
                    .sum::<f32>()
                    / (h * w) as f32;
                assert!((out[bi * c + ch] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn global_pool_adjoint() {
        // <gp(x), dy> == <x, gp_bwd(dy)>
        let (b, h, w, c) = (2, 3, 3, 4);
        let mut g = Pcg32::new(2);
        let x = g.normal_vec(b * h * w * c);
        let dy = g.normal_vec(b * c);
        let lhs: f64 = global_pool_forward(&x, b, h, w, c)
            .iter()
            .zip(&dy)
            .map(|(a, v)| *a as f64 * *v as f64)
            .sum();
        let rhs: f64 = x
            .iter()
            .zip(&global_pool_backward(&dy, b, h, w, c))
            .map(|(a, v)| *a as f64 * *v as f64)
            .sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn into_variants_overwrite_dirty_storage() {
        let (b, h, w, c) = (1, 2, 2, 3);
        let mut g = Pcg32::new(9);
        let x = g.normal_vec(b * h * w * c);
        let want = global_pool_forward(&x, b, h, w, c);
        let mut out = vec![f32::NAN; b * c];
        global_pool_forward_into(&x, b, h, w, c, &mut out);
        assert_eq!(out, want);
        let dy = g.normal_vec(b * c);
        let wantb = global_pool_backward(&dy, b, h, w, c);
        let mut dx = vec![f32::NAN; b * h * w * c];
        global_pool_backward_into(&dy, b, h, w, c, &mut dx);
        assert_eq!(dx, wantb);
    }

    #[test]
    fn skip_downsample_adjoint() {
        // <D(skip), d> == <skip, Dᵀ(d)> for identity, channel-doubling
        // and strided shortcut geometries
        let mut rng = Pcg32::new(3);
        for g in [
            SkipGeom { h: 4, w: 4, c: 3, oh: 4, ow: 4, co: 3, stride: 1 },
            SkipGeom { h: 4, w: 4, c: 3, oh: 4, ow: 4, co: 6, stride: 1 },
            SkipGeom { h: 6, w: 6, c: 2, oh: 3, ow: 3, co: 4, stride: 2 },
            SkipGeom { h: 5, w: 5, c: 2, oh: 3, ow: 3, co: 2, stride: 2 },
            SkipGeom { h: 4, w: 4, c: 1, oh: 2, ow: 2, co: 3, stride: 2 },
        ] {
            let b = 2;
            let skip = rng.normal_vec(b * g.h * g.w * g.c);
            let d = rng.normal_vec(b * g.oh * g.ow * g.co);
            // D(skip) via skip_add into a zero map
            let mut dsk = vec![0.0f32; d.len()];
            skip_add(&mut dsk, &skip, b, &g);
            let lhs: f64 = dsk.iter().zip(&d).map(|(a, v)| *a as f64 * *v as f64).sum();
            let rhs: f64 = skip
                .iter()
                .zip(&skip_grad(&d, b, &g))
                .map(|(a, v)| *a as f64 * *v as f64)
                .sum();
            assert!((lhs - rhs).abs() < 1e-3, "{g:?}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn skip_add_duplicates_channels() {
        // co = 2c: both copies read the same source channel
        let g = SkipGeom { h: 2, w: 2, c: 2, oh: 1, ow: 1, co: 4, stride: 2 };
        let skip = vec![1.0, 2.0, 10.0, 20.0, 100.0, 200.0, 1000.0, 2000.0];
        let mut cur = vec![0.0f32; 4];
        skip_add(&mut cur, &skip, 1, &g);
        // subsample picks (0,0) -> channels [1, 2], duplicated
        assert_eq!(cur, vec![1.0, 2.0, 1.0, 2.0]);
        let ds = skip_grad(&[1.0, 2.0, 4.0, 8.0], 1, &g);
        assert_eq!(&ds[..2], &[5.0, 10.0]); // 1+4, 2+8
        assert!(ds[2..].iter().all(|&v| v == 0.0));
    }
}
