//! Shared layer-graph execution core for the two naive engines.
//!
//! Both trainers walk the same [`super::plan::Plan`]; what differs is
//! *per-matmul-layer* behaviour (what is retained and at which
//! precision, which BN variant runs, how ∂W is stored) and the
//! inter-layer gradient carrier (f32 for the standard engine, f16 for
//! the proposed one).  Everything else — the layer-graph control
//! flow, max-pool routing, global average pooling, and the residual
//! skip handling (save at block entry, parameter-free strided
//! 1×1-avg-pool + channel-duplication downsample, add after the
//! closing conv's BN, and the mirrored gradient bookkeeping) — is
//! written once here, over the [`EngineOps`] trait.
//!
//! Residual skips are f32 in both engines: the high-precision skip
//! path is the accuracy enhancement the paper incorporates (Sec. 2),
//! and `memmodel` prices it as an f32 transient
//! (`Graph::residual_skip_elems`).

use anyhow::{bail, Result};

use super::plan::{LayerPlan, SkipGeom};
use crate::bitops::simd;

/// Engine-specific per-layer ops the shared driver composes.
///
/// `Grad` is the inter-layer gradient carrier (`Vec<f32>` — identity
/// conversions — for the standard engine; `F16Vec` for the proposed
/// engine, so gradients crossing layer boundaries really are held in
/// f16 exactly as before the refactor: the driver converts at each
/// boundary and a f16→f32→f16 round-trip is lossless).
pub(crate) trait EngineOps {
    type Grad;

    fn batch(&self) -> usize;
    fn grad_to_f32(g: Self::Grad) -> Vec<f32>;
    fn grad_from_f32(v: Vec<f32>) -> Self::Grad;

    /// One matmul layer (dense or conv) forward + batch norm;
    /// retains whatever this engine's backward needs when `retain`.
    fn matmul_forward(
        &mut self,
        cur: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        retain: bool,
    ) -> Result<Vec<f32>>;

    /// One matmul layer backward (BN backward, ∂W/∂β production or
    /// application, ∂X); consumes the f32 gradient w.r.t. this
    /// layer's BN output, returns the f32 gradient w.r.t. its input
    /// (empty for the first layer).
    fn matmul_backward(
        &mut self,
        dnext: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        lr: f32,
    ) -> Result<Vec<f32>>;

    /// 2×2 max-pool forward; the engine stores its own mask format
    /// (pushed in layer order — the backward pops in reverse).
    fn pool_forward(&mut self, cur: Vec<f32>, h: usize, w: usize, c: usize, retain: bool)
        -> Vec<f32>;
    fn pool_backward(&mut self, dnext: Vec<f32>, h: usize, w: usize, c: usize) -> Vec<f32>;
}

/// Forward through the whole layer graph; returns logits.  `retain`
/// disables residual storage for eval (skip buffers are still
/// consumed — they are part of the function value, not of the
/// retained state).
pub(crate) fn forward_plan<E: EngineOps>(
    e: &mut E,
    layers: &[LayerPlan],
    x: &[f32],
    retain: bool,
) -> Result<Vec<f32>> {
    let b = e.batch();
    let mut cur = x.to_vec();
    let mut wi = 0usize;
    let mut skips: Vec<Vec<f32>> = Vec::new();
    for layer in layers {
        match layer {
            LayerPlan::Dense { .. } | LayerPlan::Conv { .. } => {
                cur = e.matmul_forward(cur, wi, layer, retain)?;
                wi += 1;
            }
            LayerPlan::MaxPool { h, w, c, .. } => {
                cur = e.pool_forward(cur, *h, *w, *c, retain);
            }
            LayerPlan::GlobalPool { h, w, c } => {
                cur = global_pool_forward(&cur, b, *h, *w, *c);
            }
            LayerPlan::Residual { save: true, .. } => skips.push(cur.clone()),
            LayerPlan::Residual { save: false, skip } => {
                let s = skips.pop().ok_or_else(|| {
                    anyhow::anyhow!("residual add without a saved skip (plan bug)")
                })?;
                skip_add(&mut cur, &s, b, skip);
            }
            LayerPlan::Flatten => { /* layout already flat NHWC */ }
        }
    }
    if !skips.is_empty() {
        bail!("unconsumed residual skip (plan bug)");
    }
    Ok(cur)
}

/// Backward through the whole layer graph, consuming ∂logits.
pub(crate) fn backward_plan<E: EngineOps>(
    e: &mut E,
    layers: &[LayerPlan],
    dlogits: Vec<f32>,
    lr: f32,
) -> Result<()> {
    let b = e.batch();
    let mut wi = layers.iter().filter(|l| l.weight_len() > 0).count();
    let mut dcur = E::grad_from_f32(dlogits);
    // gradients of pending skip branches: recorded at the block
    // output (Residual close, seen first in reverse), merged into the
    // main gradient at the block input (Residual save)
    let mut skip_grads: Vec<Vec<f32>> = Vec::new();
    for layer in layers.iter().rev() {
        match layer {
            LayerPlan::Dense { .. } | LayerPlan::Conv { .. } => {
                wi -= 1;
                let d = E::grad_to_f32(dcur);
                let dx = e.matmul_backward(d, wi, layer, lr)?;
                dcur = E::grad_from_f32(dx);
            }
            LayerPlan::MaxPool { h, w, c, .. } => {
                let d = E::grad_to_f32(dcur);
                dcur = E::grad_from_f32(e.pool_backward(d, *h, *w, *c));
            }
            LayerPlan::GlobalPool { h, w, c } => {
                let d = E::grad_to_f32(dcur);
                dcur = E::grad_from_f32(global_pool_backward(&d, b, *h, *w, *c));
            }
            LayerPlan::Residual { save: false, skip } => {
                // d(out)/d(skip) is the downsample adjoint; the block
                // path receives the gradient unchanged (the add is an
                // identity towards the closing conv's BN output)
                let d = E::grad_to_f32(dcur);
                skip_grads.push(skip_grad(&d, b, skip));
                dcur = E::grad_from_f32(d);
            }
            LayerPlan::Residual { save: true, .. } => {
                let g = skip_grads.pop().ok_or_else(|| {
                    anyhow::anyhow!("residual save without a recorded skip grad (plan bug)")
                })?;
                let mut d = E::grad_to_f32(dcur);
                simd::add_assign_f32(&mut d, &g);
                dcur = E::grad_from_f32(d);
            }
            LayerPlan::Flatten => {}
        }
    }
    if !skip_grads.is_empty() {
        bail!("unconsumed residual skip grad (plan bug)");
    }
    Ok(())
}

// ------------------------------------------------ engine-independent ops

/// Global average pool: NHWC (b, h, w, c) → (b, c).
pub(crate) fn global_pool_forward(x: &[f32], b: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let hw = h * w;
    debug_assert_eq!(x.len(), b * hw * c);
    let inv = 1.0 / hw as f32;
    let mut out = vec![0.0f32; b * c];
    for bi in 0..b {
        let orow = &mut out[bi * c..(bi + 1) * c];
        for p in 0..hw {
            let xrow = &x[(bi * hw + p) * c..][..c];
            simd::add_assign_f32(orow, xrow);
        }
        for v in orow.iter_mut() {
            *v *= inv;
        }
    }
    out
}

/// Global average pool backward: every position receives ∂y/(h·w).
pub(crate) fn global_pool_backward(
    dy: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<f32> {
    let hw = h * w;
    debug_assert_eq!(dy.len(), b * c);
    let inv = 1.0 / hw as f32;
    let mut dx = vec![0.0f32; b * hw * c];
    for bi in 0..b {
        let dyr: Vec<f32> = dy[bi * c..(bi + 1) * c].iter().map(|v| v * inv).collect();
        for p in 0..hw {
            dx[(bi * hw + p) * c..][..c].copy_from_slice(&dyr);
        }
    }
    dx
}

/// Add the downsampled skip into the block-output map in place:
/// `cur[bi, oy, ox, co] += skip[bi, oy·stride, ox·stride, co mod c]`
/// — strided 1×1 average pool (pure subsample) + channel duplication.
pub(crate) fn skip_add(cur: &mut [f32], skip: &[f32], b: usize, g: &SkipGeom) {
    debug_assert_eq!(cur.len(), b * g.oh * g.ow * g.co);
    debug_assert_eq!(skip.len(), b * g.h * g.w * g.c);
    if g.stride == 1 && g.c == g.co {
        simd::add_assign_f32(cur, skip);
        return;
    }
    let s = g.stride;
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let src = ((bi * g.h + oy * s) * g.w + ox * s) * g.c;
                let dst = ((bi * g.oh + oy) * g.ow + ox) * g.co;
                if g.c == g.co {
                    simd::add_assign_f32(&mut cur[dst..dst + g.co], &skip[src..src + g.c]);
                } else {
                    for co in 0..g.co {
                        cur[dst + co] += skip[src + co % g.c];
                    }
                }
            }
        }
    }
}

/// Adjoint of the downsample shortcut: gradient w.r.t. the saved
/// skip.  Sampled positions accumulate the sums of their duplicated
/// channels; unsampled positions (stride > 1) get zero.
pub(crate) fn skip_grad(d: &[f32], b: usize, g: &SkipGeom) -> Vec<f32> {
    debug_assert_eq!(d.len(), b * g.oh * g.ow * g.co);
    if g.stride == 1 && g.c == g.co {
        return d.to_vec();
    }
    let s = g.stride;
    let mut ds = vec![0.0f32; b * g.h * g.w * g.c];
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let dst = ((bi * g.h + oy * s) * g.w + ox * s) * g.c;
                let src = ((bi * g.oh + oy) * g.ow + ox) * g.co;
                if g.c == g.co {
                    simd::add_assign_f32(&mut ds[dst..dst + g.c], &d[src..src + g.co]);
                } else {
                    for co in 0..g.co {
                        ds[dst + co % g.c] += d[src + co];
                    }
                }
            }
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn global_pool_forward_means() {
        let (b, h, w, c) = (2, 2, 3, 2);
        let mut g = Pcg32::new(1);
        let x = g.normal_vec(b * h * w * c);
        let out = global_pool_forward(&x, b, h, w, c);
        for bi in 0..b {
            for ch in 0..c {
                let want: f32 = (0..h * w)
                    .map(|p| x[(bi * h * w + p) * c + ch])
                    .sum::<f32>()
                    / (h * w) as f32;
                assert!((out[bi * c + ch] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn global_pool_adjoint() {
        // <gp(x), dy> == <x, gp_bwd(dy)>
        let (b, h, w, c) = (2, 3, 3, 4);
        let mut g = Pcg32::new(2);
        let x = g.normal_vec(b * h * w * c);
        let dy = g.normal_vec(b * c);
        let lhs: f64 = global_pool_forward(&x, b, h, w, c)
            .iter()
            .zip(&dy)
            .map(|(a, v)| *a as f64 * *v as f64)
            .sum();
        let rhs: f64 = x
            .iter()
            .zip(&global_pool_backward(&dy, b, h, w, c))
            .map(|(a, v)| *a as f64 * *v as f64)
            .sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn skip_downsample_adjoint() {
        // <D(skip), d> == <skip, Dᵀ(d)> for identity, channel-doubling
        // and strided shortcut geometries
        let mut rng = Pcg32::new(3);
        for g in [
            SkipGeom { h: 4, w: 4, c: 3, oh: 4, ow: 4, co: 3, stride: 1 },
            SkipGeom { h: 4, w: 4, c: 3, oh: 4, ow: 4, co: 6, stride: 1 },
            SkipGeom { h: 6, w: 6, c: 2, oh: 3, ow: 3, co: 4, stride: 2 },
            SkipGeom { h: 5, w: 5, c: 2, oh: 3, ow: 3, co: 2, stride: 2 },
            SkipGeom { h: 4, w: 4, c: 1, oh: 2, ow: 2, co: 3, stride: 2 },
        ] {
            let b = 2;
            let skip = rng.normal_vec(b * g.h * g.w * g.c);
            let d = rng.normal_vec(b * g.oh * g.ow * g.co);
            // D(skip) via skip_add into a zero map
            let mut dsk = vec![0.0f32; d.len()];
            skip_add(&mut dsk, &skip, b, &g);
            let lhs: f64 = dsk.iter().zip(&d).map(|(a, v)| *a as f64 * *v as f64).sum();
            let rhs: f64 = skip
                .iter()
                .zip(&skip_grad(&d, b, &g))
                .map(|(a, v)| *a as f64 * *v as f64)
                .sum();
            assert!((lhs - rhs).abs() < 1e-3, "{g:?}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn skip_add_duplicates_channels() {
        // co = 2c: both copies read the same source channel
        let g = SkipGeom { h: 2, w: 2, c: 2, oh: 1, ow: 1, co: 4, stride: 2 };
        let skip = vec![1.0, 2.0, 10.0, 20.0, 100.0, 200.0, 1000.0, 2000.0];
        let mut cur = vec![0.0f32; 4];
        skip_add(&mut cur, &skip, 1, &g);
        // subsample picks (0,0) -> channels [1, 2], duplicated
        assert_eq!(cur, vec![1.0, 2.0, 1.0, 2.0]);
        let ds = skip_grad(&[1.0, 2.0, 4.0, 8.0], 1, &g);
        assert_eq!(&ds[..2], &[5.0, 10.0]); // 1+4, 2+8
        assert!(ds[2..].iter().all(|&v| v == 0.0));
    }
}
