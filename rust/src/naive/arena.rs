//! Step arena: recycled buffer storage that makes steady-state
//! training steps **allocation-free**.
//!
//! The paper's whole argument is that the *peak memory of a training
//! step* gates on-device learning — yet the engines used to allocate
//! fresh `Vec`s at every layer boundary of every step, so the step
//! footprint was emergent (whatever the allocator happened to do)
//! rather than scheduled.  The [`StepArena`] turns every transient of
//! the step — activations, gradients, packed bit panels, BN
//! statistics, pool masks, f16 gradient carriers — into a checkout
//! from a typed free-list pool:
//!
//! - [`StepArena::take_f32`] hands out a buffer with the *smallest
//!   adequate capacity* (best fit); a miss allocates once and the
//!   buffer joins the pool on [`StepArena::put_f32`] forever after;
//! - because a training step performs the same sequence of takes and
//!   puts every time (shapes are fixed by the [`super::plan::Plan`]),
//!   the pool reaches a fixed point after **one warmup step**: every
//!   subsequent take hits the pool and the step performs *zero* heap
//!   allocations (`memtrack::alloc_count` asserts this in
//!   rust/tests/memtrack_step.rs);
//! - the pool's steady composition *is* the step's transient memory
//!   schedule: buffers are slots, the take/put pattern is the
//!   liveness assignment, and [`StepArena::heap_bytes`] is the
//!   scheduled footprint `memmodel::step_envelope` prices.
//!
//! Buffers keep their allocation when parked, so the arena trades a
//! bounded, *scheduled* resident footprint (microbatch-sized — see
//! the trainers' gradient accumulation) for a step that never touches
//! the system allocator.

use crate::bitops::{BitMask, BitMatrix};
use crate::util::f16::F16Vec;

/// One typed free list: buffers sorted ascending by capacity.
#[derive(Debug, Default)]
struct FreeList<T> {
    bufs: Vec<Vec<T>>,
    /// Sum of parked capacities, in elements.
    pooled: usize,
    /// Sum of checked-out capacities, in elements (at take time).
    outstanding: usize,
    misses: usize,
    takes: usize,
}

impl<T: Clone + Default> FreeList<T> {
    /// Best-fit checkout: smallest parked buffer with capacity ≥
    /// `len`, else a fresh exact-capacity allocation (a *miss*).
    /// Contents are unspecified (stale prior data past `len` is
    /// truncated; the prefix may hold old values) — callers that
    /// need zeros use the `_zeroed` wrappers.
    fn take(&mut self, len: usize) -> Vec<T> {
        self.takes += 1;
        if len == 0 {
            return Vec::new(); // capacity-0: never touches the pool
        }
        // bufs is sorted by capacity: first fit == best fit
        let idx = self.bufs.partition_point(|b| b.capacity() < len);
        if idx < self.bufs.len() {
            let mut v = self.bufs.remove(idx);
            self.pooled -= v.capacity();
            self.outstanding += v.capacity();
            if v.len() < len {
                v.resize(len, T::default());
            } else {
                v.truncate(len);
            }
            return v;
        }
        self.misses += 1;
        let mut v = Vec::with_capacity(len);
        v.resize(len, T::default());
        self.outstanding += v.capacity();
        v
    }

    fn take_zeroed(&mut self, len: usize) -> Vec<T> {
        let mut v = self.take(len);
        v.clear();
        v.resize(len, T::default());
        v
    }

    fn put(&mut self, v: Vec<T>) {
        let cap = v.capacity();
        if cap == 0 {
            return; // empty vecs never held heap memory
        }
        self.outstanding = self.outstanding.saturating_sub(cap);
        self.pooled += cap;
        let idx = self.bufs.partition_point(|b| b.capacity() < cap);
        self.bufs.insert(idx, v);
    }
}

/// Typed recycling pools for every buffer class of a training step.
#[derive(Debug, Default)]
pub struct StepArena {
    f32s: FreeList<f32>,
    u64s: FreeList<u64>, // BitMatrix / BitMask words
    u16s: FreeList<u16>, // F16Vec payloads
    u32s: FreeList<u32>, // pool argmax masks (standard engine)
}

impl StepArena {
    pub fn new() -> StepArena {
        StepArena::default()
    }

    // -------------------------------------------------------- f32
    /// Checkout with unspecified contents (for buffers the caller
    /// fully overwrites, e.g. GEMM outputs).
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        self.f32s.take(len)
    }

    /// Checkout guaranteed all-zero (for accumulation targets).
    pub fn take_zeroed_f32(&mut self, len: usize) -> Vec<f32> {
        self.f32s.take_zeroed(len)
    }

    /// Checkout holding a copy of `src`.
    pub fn take_copy_f32(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.f32s.take(src.len());
        v.copy_from_slice(src);
        v
    }

    pub fn put_f32(&mut self, v: Vec<f32>) {
        self.f32s.put(v);
    }

    // -------------------------------------------------------- u32
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        self.u32s.take(len)
    }

    pub fn put_u32(&mut self, v: Vec<u32>) {
        self.u32s.put(v);
    }

    // -------------------------------------------------- bit storage
    /// Packed matrix with **unspecified** word contents — for targets
    /// of `pack_into` / `pack_f16_t_into` / `im2col_packed_into`,
    /// which overwrite (or pre-zero) every word themselves.
    pub fn take_bits(&mut self, rows: usize, cols: usize) -> BitMatrix {
        let wpr = cols.div_ceil(64);
        let data = self.u64s.take(rows * wpr);
        BitMatrix { rows, cols, words_per_row: wpr, data }
    }

    /// Zeroed packed matrix — for OR-style bit accumulation targets.
    pub fn take_zeroed_bits(&mut self, rows: usize, cols: usize) -> BitMatrix {
        let wpr = cols.div_ceil(64);
        let data = self.u64s.take_zeroed(rows * wpr);
        BitMatrix { rows, cols, words_per_row: wpr, data }
    }

    pub fn put_bits(&mut self, m: BitMatrix) {
        self.u64s.put(m.data);
    }

    /// Zeroed bit mask of `len` bits.
    pub fn take_mask(&mut self, len: usize) -> BitMask {
        BitMask { len, data: self.u64s.take_zeroed(len.div_ceil(64)) }
    }

    pub fn put_mask(&mut self, m: BitMask) {
        self.u64s.put(m.data);
    }

    // -------------------------------------------------------- f16
    /// f16 carrier with unspecified contents (fully overwritten by
    /// the conversion that follows every checkout).
    pub fn take_f16(&mut self, len: usize) -> F16Vec {
        F16Vec(self.u16s.take(len))
    }

    pub fn put_f16(&mut self, v: F16Vec) {
        self.u16s.put(v.0);
    }

    // -------------------------------------------------- accounting
    /// Bytes resident in the arena: parked + checked-out capacities.
    /// After a steady step (everything returned) this is the step's
    /// whole transient footprint.
    pub fn heap_bytes(&self) -> usize {
        (self.f32s.pooled + self.f32s.outstanding) * 4
            + (self.u64s.pooled + self.u64s.outstanding) * 8
            + (self.u16s.pooled + self.u16s.outstanding) * 2
            + (self.u32s.pooled + self.u32s.outstanding) * 4
    }

    /// Free-list misses so far — heap allocations the arena performed.
    /// Flat across steps ⇔ the steady state allocates nothing.
    pub fn misses(&self) -> usize {
        self.f32s.misses + self.u64s.misses + self.u16s.misses + self.u32s.misses
    }

    /// Total checkouts (diagnostic).
    pub fn takes(&self) -> usize {
        self.f32s.takes + self.u64s.takes + self.u16s.takes + self.u32s.takes
    }
}

/// Per-engine step context: the arena pool plus the layer-graph
/// driver's residual skip stacks (engine-owned so their backing
/// storage persists across steps — a fresh `Vec` per step would
/// reallocate its spine every step).
#[derive(Debug, Default)]
pub struct StepCtx {
    pub arena: StepArena,
    /// Saved f32 skip maps, pushed at block entry (forward).
    pub(crate) skips: Vec<Vec<f32>>,
    /// Pending skip-branch gradients, pushed at block close (backward).
    pub(crate) skip_grads: Vec<Vec<f32>>,
}

impl StepCtx {
    /// Recycle any leftover skip-stack entries (begin-step hygiene:
    /// the stacks are empty after a completed step, but an error
    /// aborting a step between a residual push and its pop would
    /// otherwise leave a stale wrong-shaped buffer for the *next*
    /// step's residual arm to consume).
    pub(crate) fn drain_skip_stacks(&mut self) {
        while let Some(v) = self.skips.pop() {
            self.arena.put_f32(v);
        }
        while let Some(v) = self.skip_grads.pop() {
            self.arena.put_f32(v);
        }
    }
}

// ===================================================================
// Step schedule: symbolic replay of the engines' arena traffic.
//
// A training step's take/put sequence is fully determined by the
// Plan, the engine, the tier, and the microbatch — so the steady
// arena pool (slot sizes = buffer capacities, slot count = peak
// concurrency under best-fit reuse) can be *planned* without running
// anything.  `plan_standard_step` / `plan_proposed_step` replay the
// same checkout sequence the trainers perform against a simulated
// free list with the identical best-fit policy; the result is the
// byte-exact steady-state arena composition `memmodel::step_envelope`
// prices and CI diffs against the measured `arena_bytes()`.
//
// DRIFT WARNING: these traces mirror `standard.rs` / `proposed.rs`
// line by line (each phase is commented with its source).  When a
// trainer's buffer flow changes, change the trace with it — the
// planned-vs-measured tests in this module and the CI regression
// step exist to catch exactly that.
// ===================================================================

use super::plan::{LayerPlan, Plan};

/// One simulated typed free list (mirror of [`FreeList`]): caps
/// sorted ascending, `allocated` = Σ missed capacities = the pool's
/// steady element count (puts conserve).
#[derive(Debug, Default, Clone)]
struct SymPool {
    caps: Vec<usize>,
    allocated: usize,
}

impl SymPool {
    fn take(&mut self, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        let idx = self.caps.partition_point(|c| *c < len);
        if idx < self.caps.len() {
            return self.caps.remove(idx);
        }
        self.allocated += len;
        len
    }

    fn put(&mut self, cap: usize) {
        if cap == 0 {
            return;
        }
        let idx = self.caps.partition_point(|c| *c < cap);
        self.caps.insert(idx, cap);
    }
}

/// Simulated [`StepArena`].
#[derive(Debug, Default, Clone)]
struct SymArena {
    f32s: SymPool,
    u64s: SymPool,
    u16s: SymPool,
    u32s: SymPool,
}

impl SymArena {
    fn bits(&mut self, rows: usize, cols: usize) -> usize {
        self.u64s.take(rows * cols.div_ceil(64))
    }

    fn mask(&mut self, len: usize) -> usize {
        self.u64s.take(len.div_ceil(64))
    }
}

/// Planned steady-state arena composition of one training step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedStep {
    pub f32_bytes: usize,
    pub u64_bytes: usize,
    pub u16_bytes: usize,
    pub u32_bytes: usize,
}

impl PlannedStep {
    pub fn total_bytes(&self) -> usize {
        self.f32_bytes + self.u64_bytes + self.u16_bytes + self.u32_bytes
    }

    fn from_sym(a: &SymArena) -> PlannedStep {
        PlannedStep {
            f32_bytes: a.f32s.allocated * 4,
            u64_bytes: a.u64s.allocated * 8,
            u16_bytes: a.u16s.allocated * 2,
            u32_bytes: a.u32s.allocated * 4,
        }
    }
}

/// Replay the standard engine's arena traffic for one step on the
/// accelerated (fused) tiers.  Mirrors `StandardTrainer`'s
/// `matmul_forward` / `matmul_backward` / pool ops / `end_chunk`.
pub fn plan_standard_step(plan: &Plan, micro: usize, chunks: usize) -> PlannedStep {
    let m = micro;
    let mut a = SymArena::default();
    let direct = chunks == 1;
    for _chunk in 0..chunks {
        // caps retained to the end of the chunk, in engine drain order
        let mut acts: Vec<usize> = Vec::new();
        let mut mus: Vec<usize> = Vec::new();
        let mut psis: Vec<usize> = Vec::new();
        let mut masks: Vec<usize> = Vec::new();
        let mut skips: Vec<usize> = Vec::new();
        // ---------------- forward (ops::forward_plan)
        let mut cur = a.f32s.take(m * plan.input_elems);
        for layer in &plan.layers {
            match *layer {
                LayerPlan::Dense { k, n, first } => {
                    let y = a.f32s.take(m * n);
                    if first {
                        let bw = a.f32s.take(k * n);
                        a.f32s.put(bw);
                    } else {
                        let xh = a.bits(m, k);
                        a.u64s.put(xh);
                    }
                    let xn = a.f32s.take(m * n);
                    let mu = a.f32s.take(n);
                    let psi = a.f32s.take(n);
                    a.f32s.put(y);
                    acts.push(cur);
                    mus.push(mu);
                    psis.push(psi);
                    acts.push(a.f32s.take(m * n)); // retained xn copy
                    cur = xn;
                }
                LayerPlan::Conv { g, cout, first } => {
                    let rows = g.rows(m);
                    let y;
                    if first {
                        let bw = a.f32s.take(g.k() * cout);
                        y = a.f32s.take(rows * cout);
                        let cols = a.f32s.take(rows * g.k());
                        a.f32s.put(cols);
                        a.f32s.put(bw);
                    } else {
                        y = a.f32s.take(rows * cout);
                        let xh = a.bits(rows, g.k());
                        let scratch = a.f32s.take(g.kside * g.kside * cout);
                        a.f32s.put(scratch);
                        a.u64s.put(xh);
                    }
                    let xn = a.f32s.take(rows * cout);
                    let mu = a.f32s.take(cout);
                    let psi = a.f32s.take(cout);
                    a.f32s.put(y);
                    acts.push(cur);
                    mus.push(mu);
                    psis.push(psi);
                    acts.push(a.f32s.take(rows * cout));
                    cur = xn;
                }
                LayerPlan::MaxPool { h, w, c, oh, ow } => {
                    let cells = m * oh * ow * c;
                    let out = a.f32s.take(cells);
                    let mask = a.u32s.take(cells);
                    a.f32s.put(cur);
                    masks.push(mask);
                    let _ = (h, w);
                    cur = out;
                }
                LayerPlan::GlobalPool { c, .. } => {
                    let out = a.f32s.take(m * c);
                    a.f32s.put(cur);
                    cur = out;
                }
                LayerPlan::Residual { save: true, skip } => {
                    skips.push(a.f32s.take(m * skip.h * skip.w * skip.c));
                }
                LayerPlan::Residual { save: false, .. } => {
                    let s = skips.pop().unwrap();
                    a.f32s.put(s);
                }
                LayerPlan::Flatten => {}
            }
        }
        // ---------------- softmax (ops::run_train_chunks)
        let dlogits = a.f32s.take(m * plan.classes);
        a.f32s.put(cur); // logits
        // ---------------- backward (ops::backward_plan)
        let mut dcur = dlogits;
        let mut skip_grads: Vec<usize> = Vec::new();
        // retained acts are indexed 2wi / 2wi+1; recover input-act
        // element counts per weight layer for the dW reference paths
        let mut wi = plan.layers.iter().filter(|l| l.weight_len() > 0).count();
        for layer in plan.layers.iter().rev() {
            match *layer {
                LayerPlan::Dense { k, n, first } => {
                    wi -= 1;
                    let rows = m;
                    let dy = a.f32s.take(rows * n);
                    let mv = a.f32s.take(n);
                    let mvx = a.f32s.take(n);
                    a.f32s.put(mv);
                    a.f32s.put(mvx);
                    a.f32s.put(dcur);
                    let dx = if first {
                        0
                    } else {
                        let wt_f = a.f32s.take(n * k);
                        let dx = a.f32s.take(rows * k);
                        a.f32s.put(wt_f);
                        dx
                    };
                    if direct {
                        if !first {
                            let xh = a.bits(rows, k);
                            a.u64s.put(xh);
                        }
                    } else {
                        let dw = a.f32s.take(k * n);
                        if !first {
                            let xh = a.bits(rows, k);
                            a.u64s.put(xh);
                        }
                        a.f32s.put(dw);
                    }
                    a.f32s.put(dy);
                    dcur = dx;
                }
                LayerPlan::Conv { g, cout, first } => {
                    wi -= 1;
                    let rows = g.rows(m);
                    let k = g.k();
                    let dy = a.f32s.take(rows * cout);
                    let mv = a.f32s.take(cout);
                    let mvx = a.f32s.take(cout);
                    a.f32s.put(mv);
                    a.f32s.put(mvx);
                    a.f32s.put(dcur);
                    let dx = if first {
                        0
                    } else {
                        let dxb = a.f32s.take(g.in_len(m));
                        let panel = a.f32s.take(rows * g.cin);
                        let wtap = a.f32s.take(cout * g.cin);
                        a.f32s.put(panel);
                        a.f32s.put(wtap);
                        dxb
                    };
                    // conv_dw_into: the accumulate arm takes its
                    // scratch dw before the shared helper runs
                    let dw = if direct { 0 } else { a.f32s.take(k * cout) };
                    if first {
                        // reference dW: zero-pad f32 im2col of the raw
                        // retained input
                        let cols = a.f32s.take(rows * k);
                        a.f32s.put(cols);
                    } else {
                        let xh = a.bits(rows, k);
                        let scratch = a.f32s.take(g.kside * g.kside * cout);
                        a.f32s.put(scratch);
                        a.u64s.put(xh);
                    }
                    a.f32s.put(dw);
                    a.f32s.put(dy);
                    dcur = dx;
                }
                LayerPlan::MaxPool { h, w, c, .. } => {
                    let dx = a.f32s.take(m * h * w * c);
                    a.u32s.put(masks.pop().unwrap());
                    a.f32s.put(dcur);
                    dcur = dx;
                }
                LayerPlan::GlobalPool { h, w, c } => {
                    let dx = a.f32s.take(m * h * w * c);
                    a.f32s.put(dcur);
                    dcur = dx;
                }
                LayerPlan::Residual { save: false, skip } => {
                    skip_grads.push(a.f32s.take(m * skip.h * skip.w * skip.c));
                }
                LayerPlan::Residual { save: true, .. } => {
                    a.f32s.put(skip_grads.pop().unwrap());
                }
                LayerPlan::Flatten => {}
            }
        }
        a.f32s.put(dcur); // recycle_grad (0 for a first-layer finish)
        debug_assert_eq!(wi, 0);
        // ---------------- end_chunk: drain retained state
        for c in acts {
            a.f32s.put(c);
        }
        for c in mus.into_iter().chain(psis) {
            a.f32s.put(c);
        }
        for c in masks {
            a.u32s.put(c);
        }
    }
    PlannedStep::from_sym(&a)
}

/// Retained-residual capacities of one proposed-engine layer (the
/// trace mirror of `proposed::Residuals`).
#[derive(Default, Clone, Copy)]
struct SymRes {
    xhat: usize,    // u64 words
    x_first: usize, // f32
    ste: usize,     // u64
    bn_sign: usize, // u64
    psi: usize,     // u16
    omega: usize,   // u16
    dw_sign: usize, // u64
}

/// Trace mirror of `ProposedTrainer::matmul_bn_forward` (fused
/// tiers).  Consumes the incoming activation cap, returns the new
/// one (x_next) plus the layer's retained residual caps.
#[allow(clippy::too_many_arguments)]
fn sym_prop_forward(
    a: &mut SymArena,
    cur: usize,
    cur_len: usize,
    rows: usize,
    k: usize,
    n: usize,
    first: bool,
    conv: bool,
) -> (usize, SymRes) {
    let mut r = SymRes::default();
    let y;
    if first {
        let w = a.f32s.take(k * n);
        y = if conv {
            let cols = a.f32s.take(rows * k);
            let out = a.f32s.take(rows * n);
            a.f32s.put(cols);
            out
        } else {
            a.f32s.take(rows * n)
        };
        a.f32s.put(w);
        r.x_first = cur; // retained
    } else {
        r.ste = a.mask(cur_len);
        r.xhat = a.bits(rows, k);
        a.f32s.put(cur);
        y = a.f32s.take(rows * n);
    }
    // BN l1 (beta/x_next/psi/omega/mu f32 scratch + zeroed packed
    // signs; psi/omega re-encode into retained f16 carriers)
    let beta = a.f32s.take(n);
    let x_next = a.f32s.take(rows * n);
    let psi = a.f32s.take(n);
    let omega = a.f32s.take(n);
    let mu = a.f32s.take(n);
    r.bn_sign = a.bits(rows, n);
    a.f32s.put(y);
    a.f32s.put(beta);
    a.f32s.put(mu);
    r.psi = a.u16s.take(n);
    r.omega = a.u16s.take(n);
    a.f32s.put(psi);
    a.f32s.put(omega);
    (x_next, r)
}

/// Trace mirror of the backward driver conversions +
/// `ProposedTrainer::matmul_bn_backward` / `accumulate_dw` (fused
/// tiers).  Consumes the incoming f16 gradient cap, returns the
/// upstream one (0 after the first layer).
#[allow(clippy::too_many_arguments)]
fn sym_prop_backward(
    a: &mut SymArena,
    dcur16: usize,
    rows: usize,
    k: usize,
    n: usize,
    first: bool,
    conv: Option<(crate::bitops::ConvGeom, usize)>,
    r: &mut SymRes,
    single: bool,
) -> usize {
    // driver: grad_to_f32 before matmul_backward
    let dnext = a.f32s.take(rows * n);
    a.u16s.put(dcur16);
    // BN backward scratch
    let dy = a.f32s.take(rows * n);
    let psi = a.f32s.take(n);
    let omega = a.f32s.take(n);
    let mv = a.f32s.take(n);
    let mvx = a.f32s.take(n);
    a.f32s.put(psi);
    a.f32s.put(omega);
    a.f32s.put(mv);
    a.f32s.put(mvx);
    a.f32s.put(dnext);
    // accumulate_dw: first-layer convs im2col their retained input
    let first_cols = match (first, conv) {
        (true, Some(_)) => a.f32s.take(rows * k),
        _ => 0,
    };
    if single {
        let dw = a.f32s.take(k * n);
        r.dw_sign = a.bits(k, n);
        a.f32s.put(dw);
    } else {
        // dw_acc is persistent (mem::take, not arena); only the
        // per-chunk scratch comes from the pool
        let scratch = a.f32s.take(k * n);
        a.f32s.put(scratch);
    }
    a.f32s.put(first_cols);
    // dX
    let (dx, dx_len) = if first {
        (0, 0)
    } else {
        match conv {
            None => {
                let wt_f = a.f32s.take(n * k);
                let dx = a.f32s.take(rows * k);
                a.f32s.put(wt_f);
                (dx, rows * k)
            }
            Some((g, m)) => {
                let dx = a.f32s.take(g.in_len(m));
                let panel = a.f32s.take(rows * g.cin);
                let wtap = a.f32s.take(n * g.cin);
                a.f32s.put(panel);
                a.f32s.put(wtap);
                (dx, g.in_len(m))
            }
        }
    };
    a.f32s.put(dy);
    // driver: grad_from_f32 of dx
    if first {
        0
    } else {
        let h = a.u16s.take(dx_len);
        a.f32s.put(dx);
        h
    }
}

/// Replay the proposed engine's arena traffic for one step on the
/// accelerated (fused) tiers.  Mirrors `ProposedTrainer`'s
/// `matmul_bn_forward` / `matmul_bn_backward` / `accumulate_dw` /
/// pool ops / drain points.
pub fn plan_proposed_step(plan: &Plan, micro: usize, chunks: usize) -> PlannedStep {
    let m = micro;
    let mut a = SymArena::default();
    let single = chunks == 1;
    // single-chunk: residuals (incl. packed dW-sign) drain after the
    // update phase; accumulating: after each chunk.  Either way the
    // drain precedes the next chunk's takes, so the trace shape per
    // chunk is the same.
    for _chunk in 0..chunks {
        let mut res: Vec<SymRes> = Vec::new();
        let mut masks: Vec<usize> = Vec::new();
        let mut skips: Vec<usize> = Vec::new();
        // ---------------- forward
        let mut cur = a.f32s.take(m * plan.input_elems);
        let mut cur_len = m * plan.input_elems;
        for layer in &plan.layers {
            match *layer {
                LayerPlan::Dense { k, n, first } => {
                    let (x_next, r) =
                        sym_prop_forward(&mut a, cur, cur_len, m, k, n, first, false);
                    cur = x_next;
                    cur_len = m * n;
                    res.push(r);
                }
                LayerPlan::Conv { g, cout, first } => {
                    let rows = g.rows(m);
                    let (x_next, r) =
                        sym_prop_forward(&mut a, cur, cur_len, rows, g.k(), cout, first, true);
                    cur = x_next;
                    cur_len = rows * cout;
                    res.push(r);
                }
                LayerPlan::MaxPool { h, w, c, oh, ow } => {
                    let cells = m * oh * ow * c;
                    let out = a.f32s.take(cells);
                    let mask32 = a.u32s.take(cells);
                    a.f32s.put(cur);
                    masks.push(a.mask(m * h * w * c));
                    a.u32s.put(mask32);
                    cur = out;
                    cur_len = cells;
                }
                LayerPlan::GlobalPool { c, .. } => {
                    let out = a.f32s.take(m * c);
                    a.f32s.put(cur);
                    cur = out;
                    cur_len = m * c;
                }
                LayerPlan::Residual { save: true, skip } => {
                    skips.push(a.f32s.take(m * skip.h * skip.w * skip.c));
                }
                LayerPlan::Residual { save: false, .. } => a.f32s.put(skips.pop().unwrap()),
                LayerPlan::Flatten => {}
            }
        }
        // ---------------- softmax + f16 carrier of dlogits
        let dlogits = a.f32s.take(m * plan.classes);
        a.f32s.put(cur);
        let mut dcur16 = a.u16s.take(m * plan.classes);
        a.f32s.put(dlogits);
        // ---------------- backward
        let mut skip_grads: Vec<usize> = Vec::new();
        let mut wi = plan.layers.iter().filter(|l| l.weight_len() > 0).count();
        for layer in plan.layers.iter().rev() {
            match *layer {
                LayerPlan::Dense { k, n, first } => {
                    wi -= 1;
                    dcur16 = sym_prop_backward(
                        &mut a, dcur16, m, k, n, first, None, &mut res[wi], single,
                    );
                }
                LayerPlan::Conv { g, cout, first } => {
                    wi -= 1;
                    dcur16 = sym_prop_backward(
                        &mut a,
                        dcur16,
                        g.rows(m),
                        g.k(),
                        cout,
                        first,
                        Some((g, m)),
                        &mut res[wi],
                        single,
                    );
                }
                LayerPlan::MaxPool { h, w, c, oh, ow } => {
                    let d = a.f32s.take(m * oh * ow * c);
                    a.u16s.put(dcur16);
                    let cells_in = m * h * w * c;
                    let dx = a.f32s.take(cells_in);
                    a.u64s.put(masks.pop().unwrap());
                    a.f32s.put(d);
                    dcur16 = a.u16s.take(cells_in);
                    a.f32s.put(dx);
                }
                LayerPlan::GlobalPool { h, w, c } => {
                    let d = a.f32s.take(m * c);
                    a.u16s.put(dcur16);
                    let dx = a.f32s.take(m * h * w * c);
                    a.f32s.put(d);
                    dcur16 = a.u16s.take(m * h * w * c);
                    a.f32s.put(dx);
                }
                LayerPlan::Residual { save: false, skip } => {
                    let len = m * skip.oh * skip.ow * skip.co;
                    let d = a.f32s.take(len);
                    a.u16s.put(dcur16);
                    skip_grads.push(a.f32s.take(m * skip.h * skip.w * skip.c));
                    dcur16 = a.u16s.take(len);
                    a.f32s.put(d);
                }
                LayerPlan::Residual { save: true, skip } => {
                    let len = m * skip.h * skip.w * skip.c;
                    let d = a.f32s.take(len);
                    a.u16s.put(dcur16);
                    a.f32s.put(skip_grads.pop().unwrap());
                    dcur16 = a.u16s.take(len);
                    a.f32s.put(d);
                }
                LayerPlan::Flatten => {}
            }
        }
        a.u16s.put(dcur16); // recycle_grad
        debug_assert_eq!(wi, 0);
        // ---------------- drain residuals + masks
        for r in res {
            a.u64s.put(r.xhat);
            a.f32s.put(r.x_first);
            a.u64s.put(r.ste);
            a.u64s.put(r.bn_sign);
            a.u16s.put(r.psi);
            a.u16s.put(r.omega);
            a.u64s.put(r.dw_sign);
        }
        for c in masks {
            a.u64s.put(c);
        }
    }
    PlannedStep::from_sym(&a)
}

/// Replay the **forward-only inference** arena traffic of
/// `serve::PackedInferEngine` on the accelerated (fused) tiers:
/// one forward at every batch size `max_batch..=1` descending —
/// exactly the engine's `warmup()` schedule — so the result is the
/// steady scratch pool any batch size ≤ `max_batch` then serves from
/// allocation-free.  `proposed` selects the Algorithm 2 forward
/// (ℓ1 BN + packed sign panel) over Algorithm 1 (ℓ2 BN).
///
/// DRIFT WARNING: mirrors `serve/engine.rs` take/put for take/put;
/// the planned-vs-measured test below catches divergence.
pub fn plan_infer_forward(plan: &Plan, proposed: bool, max_batch: usize) -> PlannedStep {
    let mut a = SymArena::default();
    for b in (1..=max_batch).rev() {
        let mut skips: Vec<usize> = Vec::new();
        let mut cur = a.f32s.take(b * plan.input_elems);
        let mut cur_len = b * plan.input_elems;
        for layer in &plan.layers {
            match *layer {
                LayerPlan::Dense { k, n, first } => {
                    cur = if proposed {
                        sym_infer_prop(&mut a, cur, b, k, n, first, None)
                    } else {
                        sym_infer_std(&mut a, cur, b, k, n, first, None)
                    };
                    cur_len = b * n;
                }
                LayerPlan::Conv { g, cout, first } => {
                    let rows = g.rows(b);
                    cur = if proposed {
                        sym_infer_prop(&mut a, cur, rows, g.k(), cout, first, Some(g))
                    } else {
                        sym_infer_std(&mut a, cur, rows, g.k(), cout, first, Some(g))
                    };
                    cur_len = rows * cout;
                }
                LayerPlan::MaxPool { c, oh, ow, .. } => {
                    let cells = b * oh * ow * c;
                    let out = a.f32s.take(cells);
                    let mask = a.u32s.take(cells);
                    a.f32s.put(cur);
                    a.u32s.put(mask);
                    cur = out;
                    cur_len = cells;
                }
                LayerPlan::GlobalPool { c, .. } => {
                    let out = a.f32s.take(b * c);
                    a.f32s.put(cur);
                    cur = out;
                    cur_len = b * c;
                }
                LayerPlan::Residual { save: true, .. } => {
                    skips.push(a.f32s.take(cur_len));
                }
                LayerPlan::Residual { save: false, .. } => a.f32s.put(skips.pop().unwrap()),
                LayerPlan::Flatten => {}
            }
        }
        a.f32s.put(cur); // infer_into recycles the logits
    }
    PlannedStep::from_sym(&a)
}

/// One standard-forward matmul+BN of the inference engine
/// (serve/engine.rs `forward_standard`, accelerated tiers).
fn sym_infer_std(
    a: &mut SymArena,
    cur: usize,
    rows: usize,
    k: usize,
    n: usize,
    first: bool,
    conv: Option<crate::bitops::ConvGeom>,
) -> usize {
    let y;
    match conv {
        None => {
            y = a.f32s.take(rows * n);
            if first {
                let bw = a.f32s.take(k * n);
                a.f32s.put(bw);
            } else {
                let xh = a.bits(rows, k);
                a.u64s.put(xh);
            }
        }
        Some(g) => {
            if first {
                let bw = a.f32s.take(k * n);
                y = a.f32s.take(rows * n);
                let cols = a.f32s.take(rows * k);
                a.f32s.put(cols);
                a.f32s.put(bw);
            } else {
                y = a.f32s.take(rows * n);
                let xh = a.bits(rows, k);
                let scratch = a.f32s.take(g.kside * g.kside * n);
                a.f32s.put(scratch);
                a.u64s.put(xh);
            }
        }
    }
    let xn = a.f32s.take(rows * n);
    let mu = a.f32s.take(n);
    let psi = a.f32s.take(n);
    a.f32s.put(y);
    a.f32s.put(cur);
    a.f32s.put(mu);
    a.f32s.put(psi);
    xn
}

/// One proposed-forward matmul+BN of the inference engine
/// (serve/engine.rs `forward_proposed`, accelerated tiers).
fn sym_infer_prop(
    a: &mut SymArena,
    cur: usize,
    rows: usize,
    k: usize,
    n: usize,
    first: bool,
    conv: Option<crate::bitops::ConvGeom>,
) -> usize {
    let y;
    if first {
        let w = a.f32s.take(k * n);
        y = match conv {
            None => a.f32s.take(rows * n),
            Some(_) => {
                let cols = a.f32s.take(rows * k);
                let out = a.f32s.take(rows * n);
                a.f32s.put(cols);
                out
            }
        };
        a.f32s.put(w);
        a.f32s.put(cur);
    } else {
        let xh = a.bits(rows, k);
        a.f32s.put(cur);
        y = a.f32s.take(rows * n);
        a.u64s.put(xh);
    }
    let x_next = a.f32s.take(rows * n);
    let psi = a.f32s.take(n);
    let omega = a.f32s.take(n);
    let mu = a.f32s.take(n);
    let sign = a.bits(rows, n);
    a.f32s.put(y);
    a.f32s.put(psi);
    a.f32s.put(omega);
    a.f32s.put(mu);
    a.u64s.put(sign);
    x_next
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_fit_reuses_smallest_adequate() {
        let mut a = StepArena::new();
        let small = a.take_f32(10);
        let big = a.take_f32(1000);
        assert_eq!(a.misses(), 2);
        a.put_f32(small);
        a.put_f32(big);
        // a request for 8 must come from the 10-cap buffer, not 1000
        let v = a.take_f32(8);
        assert_eq!(a.misses(), 2, "pool hit expected");
        assert_eq!(v.capacity(), 10);
        assert_eq!(v.len(), 8);
        a.put_f32(v);
        // a request for 500 skips the 10-cap and takes the 1000-cap
        let v = a.take_f32(500);
        assert_eq!(a.misses(), 2);
        assert_eq!(v.capacity(), 1000);
        a.put_f32(v);
        // larger than anything pooled: a miss
        let v = a.take_f32(2000);
        assert_eq!(a.misses(), 3);
        a.put_f32(v);
    }

    #[test]
    fn steady_sequences_stop_missing() {
        // the zero-alloc guarantee in miniature: a repeated take/put
        // pattern misses only on its first round
        let mut a = StepArena::new();
        let mut rounds_misses = Vec::new();
        for _ in 0..4 {
            let m0 = a.misses();
            let x = a.take_f32(128);
            let y = a.take_zeroed_f32(64);
            let b = a.take_bits(16, 70);
            let mask = a.take_mask(300);
            let h = a.take_f16(50);
            let u = a.take_u32(40);
            a.put_f32(x);
            a.put_f32(y);
            a.put_bits(b);
            a.put_mask(mask);
            a.put_f16(h);
            a.put_u32(u);
            rounds_misses.push(a.misses() - m0);
        }
        assert!(rounds_misses[0] > 0);
        assert_eq!(&rounds_misses[1..], &[0, 0, 0], "{rounds_misses:?}");
    }

    #[test]
    fn zeroed_and_copy_contents() {
        let mut a = StepArena::new();
        let mut v = a.take_f32(6);
        v.iter_mut().for_each(|x| *x = 7.0);
        a.put_f32(v);
        let z = a.take_zeroed_f32(4);
        assert!(z.iter().all(|&x| x == 0.0));
        a.put_f32(z);
        let c = a.take_copy_f32(&[1.0, 2.0, 3.0]);
        assert_eq!(c, vec![1.0, 2.0, 3.0]);
        a.put_f32(c);
        // zeroed packed storage really is re-zeroed (packing ORs bits)
        let mut m = a.take_zeroed_bits(2, 64);
        m.data[0] = u64::MAX;
        a.put_bits(m);
        let m2 = a.take_zeroed_bits(2, 64);
        assert!(m2.data.iter().all(|&w| w == 0));
    }

    #[test]
    fn planners_run_across_the_zoo() {
        use crate::models::{get, lower};
        for m in ["mlp_mini", "cnv_mini", "binarynet_mini", "resnete_mini", "bireal_mini"] {
            let plan = Plan::from_graph(&lower(&get(m).unwrap()).unwrap()).unwrap();
            for chunks in [1usize, 2] {
                let s = plan_standard_step(&plan, 4, chunks);
                let p = plan_proposed_step(&plan, 4, chunks);
                assert!(s.total_bytes() > 0, "{m}");
                assert!(p.total_bytes() > 0, "{m}");
                // proposed retains bit-packed activations where the
                // standard engine retains f32: far less f32 traffic
                assert!(p.f32_bytes < s.f32_bytes, "{m} chunks={chunks}");
                // replays are deterministic
                assert_eq!(s, plan_standard_step(&plan, 4, chunks), "{m}");
                assert_eq!(p, plan_proposed_step(&plan, 4, chunks), "{m}");
            }
            // the pool fixed point means chunk count does not change
            // the per-chunk slot set much: 2 chunks ≈ 1 chunk + the
            // accumulation scratch
            let one = plan_standard_step(&plan, 4, 1);
            let two = plan_standard_step(&plan, 4, 2);
            assert!(two.total_bytes() < one.total_bytes() * 2, "{m}");
        }
    }

    #[test]
    fn infer_planner_matches_measured_arena() {
        // plan_infer_forward replays serve::PackedInferEngine's
        // warmup trace: planned bytes must equal the measured arena
        // byte for byte (this is the drift tripwire)
        use crate::models::{get, lower};
        use crate::naive::{build_engine, Accel, StepEngine};
        use crate::serve::{InferAlgo, PackedInferEngine, WeightSnapshot};
        use std::sync::Arc;
        for m in ["mlp_mini", "cnv_mini", "bireal_mini"] {
            let graph = lower(&get(m).unwrap()).unwrap();
            let plan = Plan::from_graph(&graph).unwrap();
            for (algo, name, prop) in [
                (InferAlgo::Standard, "standard", false),
                (InferAlgo::Proposed, "proposed", true),
            ] {
                let tr = build_engine(name, &graph, 2, "adam", Accel::Blocked, 1).unwrap();
                let snap =
                    Arc::new(WeightSnapshot::pack(&plan, &tr.weights_snapshot(), 0).unwrap());
                let mut eng =
                    PackedInferEngine::new(&graph, algo, Accel::Blocked, 3, snap).unwrap();
                eng.warmup().unwrap();
                let planned = plan_infer_forward(&plan, prop, 3);
                assert_eq!(planned.total_bytes(), eng.arena_bytes(), "{m} {name}");
                // forward-only scratch is far below a training step's
                let step = if prop {
                    plan_proposed_step(&plan, 3, 1)
                } else {
                    plan_standard_step(&plan, 3, 1)
                };
                assert!(planned.total_bytes() < step.total_bytes(), "{m} {name}");
            }
        }
    }

    #[test]
    fn heap_bytes_tracks_pool() {
        let mut a = StepArena::new();
        let v = a.take_f32(100);
        assert!(a.heap_bytes() >= 400);
        a.put_f32(v);
        assert!(a.heap_bytes() >= 400, "parked buffers stay resident");
        let before = a.heap_bytes();
        let v = a.take_f32(50);
        a.put_f32(v);
        assert_eq!(a.heap_bytes(), before, "steady reuse adds nothing");
    }
}
