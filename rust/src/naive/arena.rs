//! Step arena: the **schedule executor** that makes steady-state
//! training and serving steps allocation-free.
//!
//! The paper's whole argument is that the *peak memory of a training
//! step* gates on-device learning.  Since PR 8 the arena no longer
//! discovers that footprint at runtime with best-fit free lists — it
//! *executes* a compiled [`super::schedule::StepSchedule`]:
//!
//! - at install time, every typed pool (f32 / u64 bit panels / f16
//!   carriers / u32 masks) pre-allocates its colored **slots** at the
//!   capacities the compiler assigned, so the resident footprint is
//!   `Σ slot capacities` from the first step and never changes;
//! - each engine pass (`train_step`, `eval`, per-batch `infer`) runs
//!   between [`StepArena::begin_pass`] / [`StepArena::end_pass`],
//!   and every `take_*` / `put_*` is checked against the pass's next
//!   [`BufEvent`] — pool, length, init mode, slot.  A divergence
//!   between engine and compiler is an immediate panic (surfaced by
//!   the `engine_parity` sweep), not a silent drift to band-test;
//! - takes hand out the slot's buffer resized in place (capacity is
//!   never exceeded, so the steady state performs **zero** heap
//!   allocations — `memtrack::alloc_count` asserts this in
//!   rust/tests/memtrack_step.rs);
//! - puts outside a pass (begin-step hygiene drains after an aborted
//!   step) fall back to capacity-matched reclaim, and
//!   [`StepArena::begin_pass`] re-provisions any slot an error path
//!   dropped — error recovery may allocate, the steady state never
//!   does.
//!
//! Because the engines install exactly the schedule the memory model
//! folds over, `memmodel::{step_envelope,serve_envelope}` equal
//! [`StepArena::heap_bytes`] *exactly* — by construction, with no
//! drift band.

use std::sync::Arc;

use super::schedule::{BufEvent, PassEvents, PoolKind, SlotTable, TakeInit};
use crate::bitops::{BitMask, BitMatrix};
use crate::util::f16::F16Vec;

/// One typed slot pool: `slots[i]` holds the parked buffer of
/// capacity `caps[i]`, or `None` while it is checked out.
#[derive(Debug, Default)]
struct SlotPool<T> {
    slots: Vec<Option<Vec<T>>>,
    caps: Vec<usize>,
}

impl<T: Clone + Default> SlotPool<T> {
    fn provision(cap: usize) -> Vec<T> {
        let mut v = Vec::with_capacity(cap);
        v.resize(cap, T::default());
        v
    }

    fn install(&mut self, caps: &[usize]) {
        self.caps = caps.to_vec();
        self.slots = caps.iter().map(|&c| Some(Self::provision(c))).collect();
    }

    /// Refill any slot whose buffer was dropped on an error path.
    fn repair(&mut self) {
        for (s, &c) in self.slots.iter_mut().zip(&self.caps) {
            if s.is_none() {
                *s = Some(Self::provision(c));
            }
        }
    }

    fn vacate(&mut self, slot: usize) -> Vec<T> {
        self.slots[slot]
            .take()
            .unwrap_or_else(|| panic!("schedule bug: slot {slot} vacant at take"))
    }

    fn take(&mut self, slot: usize, len: usize) -> Vec<T> {
        let mut v = self.vacate(slot);
        if v.len() < len {
            v.resize(len, T::default());
        } else {
            v.truncate(len);
        }
        v
    }

    fn take_zeroed(&mut self, slot: usize, len: usize) -> Vec<T> {
        let mut v = self.vacate(slot);
        v.clear();
        v.resize(len, T::default());
        v
    }

    fn put(&mut self, slot: usize, v: Vec<T>) {
        assert!(
            self.slots[slot].is_none(),
            "schedule bug: slot {slot} already occupied at put"
        );
        assert_eq!(
            v.capacity(),
            self.caps[slot],
            "schedule bug: returned capacity does not match slot {slot}"
        );
        self.slots[slot] = Some(v);
    }

    /// Out-of-pass return (hygiene drains after an aborted step): park
    /// in a vacant slot of the exact capacity, else drop — `repair`
    /// re-provisions at the next pass start.
    fn reclaim(&mut self, v: Vec<T>) {
        let cap = v.capacity();
        for i in 0..self.slots.len() {
            if self.slots[i].is_none() && self.caps[i] == cap {
                self.slots[i] = Some(v);
                return;
            }
        }
    }

    fn bytes(&self, elem: usize) -> usize {
        self.caps.iter().sum::<usize>() * elem
    }

    /// Every installed slot holds its parked buffer (nothing is
    /// checked out).
    fn parked(&self) -> bool {
        self.slots.iter().all(Option::is_some)
    }
}

/// Cursor over one pass's event stream: `events` replayed `repeats`
/// times, then `tail`.
#[derive(Debug)]
struct ActivePass {
    pass: Arc<PassEvents>,
    idx: usize,
    rep: usize,
    in_tail: bool,
}

impl ActivePass {
    fn peek(&self) -> Option<BufEvent> {
        if self.in_tail {
            self.pass.tail.get(self.idx).copied()
        } else {
            self.pass.events.get(self.idx).copied()
        }
    }

    fn advance(&mut self) {
        self.idx += 1;
        if !self.in_tail && self.idx == self.pass.events.len() {
            self.rep += 1;
            self.idx = 0;
            if self.rep >= self.pass.repeats {
                self.in_tail = true;
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.in_tail && self.idx >= self.pass.tail.len()
    }
}

/// The slot-table executor for every buffer class of a step.
#[derive(Debug, Default)]
pub struct StepArena {
    f32s: SlotPool<f32>,
    u64s: SlotPool<u64>, // BitMatrix / BitMask words
    u16s: SlotPool<u16>, // F16Vec payloads
    u32s: SlotPool<u32>, // pool argmax masks (standard engine)
    stream: Option<ActivePass>,
}

impl StepArena {
    pub fn new() -> StepArena {
        StepArena::default()
    }

    /// Pre-allocate every colored slot.  Called once per engine at
    /// construction (and again by `install_schedule`); after this the
    /// resident footprint is fixed.
    pub fn install(&mut self, slots: &SlotTable) {
        assert!(self.stream.is_none(), "install during an active pass");
        self.f32s.install(&slots.caps[PoolKind::F32.idx()]);
        self.u64s.install(&slots.caps[PoolKind::U64.idx()]);
        self.u16s.install(&slots.caps[PoolKind::F16.idx()]);
        self.u32s.install(&slots.caps[PoolKind::U32.idx()]);
    }

    /// Start executing a pass's event stream.  Repairs any slot an
    /// aborted step dropped (steady-state no-op).
    pub fn begin_pass(&mut self, pass: Arc<PassEvents>) {
        assert!(
            self.stream.is_none(),
            "begin_pass('{}') with a pass already active",
            pass.name
        );
        self.f32s.repair();
        self.u64s.repair();
        self.u16s.repair();
        self.u32s.repair();
        let in_tail = pass.events.is_empty();
        self.stream = Some(ActivePass { pass, idx: 0, rep: 0, in_tail });
    }

    /// Finish the active pass, asserting the stream was fully
    /// consumed — a short count means the engine skipped scheduled
    /// work.
    pub fn end_pass(&mut self) {
        let st = self.stream.take().expect("end_pass without begin_pass");
        assert!(
            st.exhausted(),
            "pass '{}' ended early: chunk {}/{}, event {}{}",
            st.pass.name,
            st.rep,
            st.pass.repeats,
            st.idx,
            if st.in_tail { " (tail)" } else { "" }
        );
    }

    /// Drop the active pass after an engine error; subsequent hygiene
    /// puts reclaim, and the next `begin_pass` repairs the slots.
    pub fn abort_pass(&mut self) {
        self.stream = None;
    }

    fn take_event(&mut self, pool: PoolKind, len: usize, init: TakeInit) -> usize {
        let Some(st) = self.stream.as_ref() else {
            panic!("arena take ({pool:?} len {len}) outside a scheduled pass")
        };
        match st.peek() {
            Some(BufEvent::Take { pool: p, slot, len: l, init: i })
                if p == pool && l == len && i == init =>
            {
                self.stream.as_mut().unwrap().advance();
                slot
            }
            other => panic!(
                "schedule mismatch in pass '{}' (chunk {}, event {}{}): engine takes \
                 {pool:?} len {len} {init:?}, schedule says {other:?}",
                st.pass.name,
                st.rep,
                st.idx,
                if st.in_tail { " tail" } else { "" }
            ),
        }
    }

    /// `None` means no pass is active — reclaim mode.
    fn put_event(&mut self, pool: PoolKind) -> Option<usize> {
        let st = self.stream.as_ref()?;
        match st.peek() {
            Some(BufEvent::Put { pool: p, slot }) if p == pool => {
                self.stream.as_mut().unwrap().advance();
                Some(slot)
            }
            other => panic!(
                "schedule mismatch in pass '{}' (chunk {}, event {}{}): engine puts \
                 {pool:?}, schedule says {other:?}",
                st.pass.name,
                st.rep,
                st.idx,
                if st.in_tail { " tail" } else { "" }
            ),
        }
    }

    // -------------------------------------------------------- f32
    /// Checkout with unspecified contents (for buffers the caller
    /// fully overwrites, e.g. GEMM outputs).
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        let slot = self.take_event(PoolKind::F32, len, TakeInit::Raw);
        self.f32s.take(slot, len)
    }

    /// Checkout guaranteed all-zero (for accumulation targets).
    pub fn take_zeroed_f32(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        let slot = self.take_event(PoolKind::F32, len, TakeInit::Zeroed);
        self.f32s.take_zeroed(slot, len)
    }

    /// Checkout holding a copy of `src`.
    pub fn take_copy_f32(&mut self, src: &[f32]) -> Vec<f32> {
        if src.is_empty() {
            return Vec::new();
        }
        let slot = self.take_event(PoolKind::F32, src.len(), TakeInit::Copy);
        let mut v = self.f32s.vacate(slot);
        v.clear();
        v.extend_from_slice(src);
        v
    }

    pub fn put_f32(&mut self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        match self.put_event(PoolKind::F32) {
            Some(slot) => self.f32s.put(slot, v),
            None => self.f32s.reclaim(v),
        }
    }

    // -------------------------------------------------------- u32
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        if len == 0 {
            return Vec::new();
        }
        let slot = self.take_event(PoolKind::U32, len, TakeInit::Raw);
        self.u32s.take(slot, len)
    }

    pub fn put_u32(&mut self, v: Vec<u32>) {
        if v.capacity() == 0 {
            return;
        }
        match self.put_event(PoolKind::U32) {
            Some(slot) => self.u32s.put(slot, v),
            None => self.u32s.reclaim(v),
        }
    }

    // -------------------------------------------------- bit storage
    /// Packed matrix with **unspecified** word contents — for targets
    /// of `pack_into` / `pack_f16_t_into` / `im2col_packed_into`,
    /// which overwrite (or pre-zero) every word themselves.
    pub fn take_bits(&mut self, rows: usize, cols: usize) -> BitMatrix {
        let wpr = cols.div_ceil(64);
        let words = rows * wpr;
        let data = if words == 0 {
            Vec::new()
        } else {
            let slot = self.take_event(PoolKind::U64, words, TakeInit::Raw);
            self.u64s.take(slot, words)
        };
        BitMatrix { rows, cols, words_per_row: wpr, data }
    }

    /// Zeroed packed matrix — for OR-style bit accumulation targets.
    pub fn take_zeroed_bits(&mut self, rows: usize, cols: usize) -> BitMatrix {
        let wpr = cols.div_ceil(64);
        let words = rows * wpr;
        let data = if words == 0 {
            Vec::new()
        } else {
            let slot = self.take_event(PoolKind::U64, words, TakeInit::Zeroed);
            self.u64s.take_zeroed(slot, words)
        };
        BitMatrix { rows, cols, words_per_row: wpr, data }
    }

    pub fn put_bits(&mut self, m: BitMatrix) {
        self.put_u64_words(m.data);
    }

    /// Zeroed bit mask of `len` bits.
    pub fn take_mask(&mut self, len: usize) -> BitMask {
        let words = len.div_ceil(64);
        let data = if words == 0 {
            Vec::new()
        } else {
            let slot = self.take_event(PoolKind::U64, words, TakeInit::Zeroed);
            self.u64s.take_zeroed(slot, words)
        };
        BitMask { len, data }
    }

    pub fn put_mask(&mut self, m: BitMask) {
        self.put_u64_words(m.data);
    }

    fn put_u64_words(&mut self, v: Vec<u64>) {
        if v.capacity() == 0 {
            return;
        }
        match self.put_event(PoolKind::U64) {
            Some(slot) => self.u64s.put(slot, v),
            None => self.u64s.reclaim(v),
        }
    }

    // -------------------------------------------------------- f16
    /// f16 carrier with unspecified contents (fully overwritten by
    /// the conversion that follows every checkout).
    pub fn take_f16(&mut self, len: usize) -> F16Vec {
        if len == 0 {
            return F16Vec(Vec::new());
        }
        let slot = self.take_event(PoolKind::F16, len, TakeInit::Raw);
        F16Vec(self.u16s.take(slot, len))
    }

    pub fn put_f16(&mut self, v: F16Vec) {
        if v.0.capacity() == 0 {
            return;
        }
        match self.put_event(PoolKind::F16) {
            Some(slot) => self.u16s.put(slot, v.0),
            None => self.u16s.reclaim(v.0),
        }
    }

    // -------------------------------------------------- accounting
    /// Bytes resident in the arena: the sum of installed slot
    /// capacities.  Constant from installation on — whether buffers
    /// are parked or checked out — and equal to the compiled
    /// schedule's `arena_bytes` by construction.
    pub fn heap_bytes(&self) -> usize {
        self.f32s.bytes(4) + self.u64s.bytes(8) + self.u16s.bytes(2) + self.u32s.bytes(4)
    }

    /// True when no pass is active and every installed slot is parked
    /// — the quiescence invariant the multi-tenant runtime asserts at
    /// each preemption boundary: a tenant handed between lanes with a
    /// buffer still checked out would leak that slot into the next
    /// lane's pass.
    pub fn idle(&self) -> bool {
        self.stream.is_none()
            && self.f32s.parked()
            && self.u64s.parked()
            && self.u16s.parked()
            && self.u32s.parked()
    }
}

/// Per-engine step context: the arena pool plus the layer-graph
/// driver's residual skip stacks (engine-owned so their backing
/// storage persists across steps — a fresh `Vec` per step would
/// reallocate its spine every step).
#[derive(Debug, Default)]
pub struct StepCtx {
    pub arena: StepArena,
    /// Saved f32 skip maps, pushed at block entry (forward).
    pub(crate) skips: Vec<Vec<f32>>,
    /// Pending skip-branch gradients, pushed at block close (backward).
    pub(crate) skip_grads: Vec<Vec<f32>>,
}

impl StepCtx {
    /// Recycle any leftover skip-stack entries (begin-step hygiene:
    /// the stacks are empty after a completed step, but an error
    /// aborting a step between a residual push and its pop would
    /// otherwise leave a stale wrong-shaped buffer for the *next*
    /// step's residual arm to consume).  Runs outside passes, so the
    /// puts reclaim.
    pub(crate) fn drain_skip_stacks(&mut self) {
        while let Some(v) = self.skips.pop() {
            self.arena.put_f32(v);
        }
        while let Some(v) = self.skip_grads.pop() {
            self.arena.put_f32(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::naive::schedule::POOLS;

    fn table(f32_caps: &[usize], u64_caps: &[usize]) -> SlotTable {
        let mut caps: [Vec<usize>; POOLS] = Default::default();
        caps[PoolKind::F32.idx()] = f32_caps.to_vec();
        caps[PoolKind::U64.idx()] = u64_caps.to_vec();
        SlotTable { caps }
    }

    fn pass(name: &str, repeats: usize, events: Vec<BufEvent>, tail: Vec<BufEvent>) -> Arc<PassEvents> {
        Arc::new(PassEvents { name: name.into(), repeats, events, tail })
    }

    fn take(slot: usize, len: usize, init: TakeInit) -> BufEvent {
        BufEvent::Take { pool: PoolKind::F32, slot, len, init }
    }

    fn put(slot: usize) -> BufEvent {
        BufEvent::Put { pool: PoolKind::F32, slot }
    }

    #[test]
    fn executes_a_scripted_pass_with_repeats() {
        let mut a = StepArena::new();
        a.install(&table(&[8, 4], &[]));
        assert_eq!(a.heap_bytes(), (8 + 4) * 4);
        let p = pass(
            "t",
            3,
            vec![
                take(0, 6, TakeInit::Raw),
                take(1, 4, TakeInit::Zeroed),
                put(1),
                put(0),
            ],
            vec![],
        );
        a.begin_pass(p);
        for _ in 0..3 {
            let mut x = a.take_f32(6);
            assert_eq!(x.len(), 6);
            x.fill(7.0);
            let z = a.take_zeroed_f32(4);
            assert!(z.iter().all(|&v| v == 0.0));
            a.put_f32(z);
            a.put_f32(x);
        }
        a.end_pass();
        // footprint never moved
        assert_eq!(a.heap_bytes(), (8 + 4) * 4);
    }

    #[test]
    fn copy_take_and_len0_rules() {
        let mut a = StepArena::new();
        a.install(&table(&[4], &[]));
        let p = pass("t", 1, vec![take(0, 3, TakeInit::Copy), put(0)], vec![]);
        a.begin_pass(p);
        let src = [1.0f32, 2.0, 3.0];
        let v = a.take_copy_f32(&src);
        assert_eq!(v, src);
        // len-0 takes and capacity-0 puts never touch the stream
        let e = a.take_f32(0);
        assert!(e.is_empty());
        a.put_f32(e);
        a.put_f32(v);
        a.end_pass();
    }

    #[test]
    fn tail_runs_after_the_repeats() {
        let mut a = StepArena::new();
        a.install(&table(&[4], &[]));
        let p = pass("t", 1, vec![take(0, 4, TakeInit::Raw)], vec![put(0)]);
        a.begin_pass(p);
        let v = a.take_f32(4);
        a.put_f32(v); // consumed from the tail
        a.end_pass();
    }

    #[test]
    #[should_panic(expected = "schedule mismatch")]
    fn wrong_length_take_panics() {
        let mut a = StepArena::new();
        a.install(&table(&[8], &[]));
        a.begin_pass(pass("t", 1, vec![take(0, 8, TakeInit::Raw), put(0)], vec![]));
        let _ = a.take_f32(5);
    }

    #[test]
    #[should_panic(expected = "ended early")]
    fn unfinished_pass_panics_at_end() {
        let mut a = StepArena::new();
        a.install(&table(&[8], &[]));
        a.begin_pass(pass("t", 2, vec![take(0, 8, TakeInit::Raw), put(0)], vec![]));
        let v = a.take_f32(8);
        a.put_f32(v);
        a.end_pass(); // only one of two chunks ran
    }

    #[test]
    fn idle_tracks_pass_state_and_checkouts() {
        let mut a = StepArena::new();
        a.install(&table(&[8], &[]));
        assert!(a.idle());
        a.begin_pass(pass("t", 1, vec![take(0, 8, TakeInit::Raw), put(0)], vec![]));
        assert!(!a.idle(), "active pass is not idle");
        let v = a.take_f32(8);
        a.put_f32(v);
        a.end_pass();
        assert!(a.idle());
        // a buffer lost on an error path leaves the arena non-idle
        // until the next begin_pass repairs the slot
        a.begin_pass(pass("t2", 1, vec![take(0, 8, TakeInit::Raw), put(0)], vec![]));
        let v = a.take_f32(8);
        a.abort_pass();
        drop(v);
        assert!(!a.idle(), "vacant slot is not idle");
        a.begin_pass(pass("t3", 1, vec![], vec![]));
        a.end_pass();
        assert!(a.idle());
    }

    #[test]
    fn abort_reclaim_and_repair() {
        let mut a = StepArena::new();
        a.install(&table(&[8, 2], &[3]));
        a.begin_pass(pass(
            "t",
            1,
            vec![take(0, 8, TakeInit::Raw), take(1, 2, TakeInit::Raw), put(0), put(1)],
            vec![],
        ));
        let big = a.take_f32(8);
        let small = a.take_f32(2);
        a.abort_pass();
        drop(big); // lost on the error path
        a.put_f32(small); // hygiene drain: reclaims into the cap-2 slot
        assert_eq!(a.heap_bytes(), (8 + 2) * 4 + 3 * 8);
        // next pass repairs the dropped slot and runs normally
        a.begin_pass(pass("t2", 1, vec![take(0, 4, TakeInit::Raw), put(0)], vec![]));
        let v = a.take_f32(4);
        a.put_f32(v);
        a.end_pass();
        assert_eq!(a.heap_bytes(), (8 + 2) * 4 + 3 * 8);
    }

    #[test]
    fn bit_buffers_masks_and_f16_route_through_their_pools() {
        let mut a = StepArena::new();
        let mut caps: [Vec<usize>; POOLS] = Default::default();
        caps[PoolKind::U64.idx()] = vec![4, 2];
        caps[PoolKind::F16.idx()] = vec![5];
        caps[PoolKind::U32.idx()] = vec![6];
        a.install(&SlotTable { caps });
        let ev = vec![
            BufEvent::Take { pool: PoolKind::U64, slot: 0, len: 4, init: TakeInit::Raw },
            BufEvent::Take { pool: PoolKind::U64, slot: 1, len: 2, init: TakeInit::Zeroed },
            BufEvent::Take { pool: PoolKind::F16, slot: 0, len: 5, init: TakeInit::Raw },
            BufEvent::Take { pool: PoolKind::U32, slot: 0, len: 6, init: TakeInit::Raw },
            BufEvent::Put { pool: PoolKind::U32, slot: 0 },
            BufEvent::Put { pool: PoolKind::F16, slot: 0 },
            BufEvent::Put { pool: PoolKind::U64, slot: 1 },
            BufEvent::Put { pool: PoolKind::U64, slot: 0 },
        ];
        a.begin_pass(pass("t", 1, ev, vec![]));
        let bits = a.take_bits(2, 100); // 2 rows × 2 words
        assert_eq!(bits.data.len(), 4);
        let mask = a.take_mask(80); // 2 words, zeroed
        assert!(mask.data.iter().all(|&w| w == 0));
        let h = a.take_f16(5);
        let m32 = a.take_u32(6);
        a.put_u32(m32);
        a.put_f16(h);
        a.put_mask(mask);
        a.put_bits(bits);
        a.end_pass();
        assert_eq!(a.heap_bytes(), (4 + 2) * 8 + 5 * 2 + 6 * 4);
    }
}
