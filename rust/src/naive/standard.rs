//! Algorithm 1 — Courbariaux & Bengio's standard BNN training step,
//! float32 everywhere, ℓ2 batch normalization.
//!
//! Memory behaviour is the point: every layer's input activations are
//! retained in f32 between forward and backward (Fig. 1's red
//! dependency), pool masks are f32-indexed, weights/momenta/grads are
//! f32 — exactly the left half of Table 2, so the tracking allocator
//! measures what the paper's standard prototype measured.
//!
//! Since the step-arena refactor every per-step buffer — retained
//! activations, BN statistics, pool masks, GEMM outputs, packed bit
//! panels, gradient transients — is a [`StepCtx`] arena checkout:
//! after one warmup step a training step performs **zero heap
//! allocations**, and ∂W/∂β accumulate across `--microbatch` chunks
//! into persistent weight-scale buffers before one deferred optimizer
//! update, so the step's peak memory is set by the microbatch, not
//! the logical batch.
//!
//! The layer-graph control flow (pooling, global pooling, residual
//! skips, the microbatch chunk loop) lives in [`super::ops`]; this
//! file implements the standard engine's per-matmul-layer
//! forward/backward over any [`ConvGeom`].  Binary×binary matmuls —
//! conv *and* hidden dense layers — run the packed XNOR path on the
//! accelerated tiers.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::arena::{StepArena, StepCtx};
use super::ops::{self, EngineOps};
use super::plan::{LayerPlan, Plan};
use super::schedule::{self, StepSchedule};
use super::{glorot_init, Accel, StepEngine};
use crate::bitops::im2col::{conv_dw_first_streaming_into, conv_fwd_first_streaming_into};
use crate::bitops::{
    conv_dx_streaming_into, im2col_packed_into, simd, subtract_pad_contrib_with,
    subtract_pad_dw_contrib_with, BitMatrix, ConvGeom, PackedWeightCache,
};
use crate::models::Graph;
use crate::optim::{OptState, Store};
use crate::util::rng::Pcg32;

pub struct StandardTrainer {
    plan: Plan,
    /// Logical batch (what `train_step` consumes per call).
    batch: usize,
    /// Execution microbatch: every per-step buffer is sized by this;
    /// gradients accumulate across the `batch / micro` chunks.
    micro: usize,
    accel: Accel,
    // parameters (f32 latent weights, clipped to [-1,1]) + BN biases
    weights: Vec<Store>,
    betas: Vec<Store>,
    opt_w: Vec<OptState>,
    opt_b: Vec<OptState>,
    // retained per chunk (drained back to the arena after each
    // chunk's backward).  Each matmul layer wi pushes exactly two f32
    // activations in order: its input at index 2·wi and its BN output
    // at 2·wi + 1.
    acts: Vec<Vec<f32>>,
    pool_masks: Vec<Vec<u32>>, // argmax index per pooled cell (f32-class storage)
    bn_mu: Vec<Vec<f32>>,
    bn_psi: Vec<Vec<f32>>,
    /// Per-step gradient accumulators (persistent, weight-scale):
    /// chunk backward passes add into these; `apply_update` consumes
    /// them once per step.  This realizes Table 2's retained-∂W row.
    dw_acc: Vec<Vec<f32>>,
    dbeta_acc: Vec<Vec<f32>>,
    /// Per-step binarized-weight cache: sign(W) is packed once per
    /// step into retained storage; invalidated on weight update.
    wcache: PackedWeightCache,
    /// The compiled buffer schedule this engine executes (train pass
    /// + eval pass, slot-colored; see `naive::schedule`).
    sched: Arc<StepSchedule>,
    /// Arena pool + driver skip stacks (see `naive::arena`).
    ctx: StepCtx,
}

impl StandardTrainer {
    pub fn new(
        graph: &Graph,
        batch: usize,
        optimizer: &str,
        accel: Accel,
        seed: u64,
    ) -> Result<StandardTrainer> {
        StandardTrainer::with_microbatch(graph, batch, 0, optimizer, accel, seed)
    }

    /// Build with gradient accumulation: the step executes in
    /// `microbatch`-sized chunks (0 = whole batch, no accumulation).
    /// `microbatch` must divide `batch`.
    pub fn with_microbatch(
        graph: &Graph,
        batch: usize,
        microbatch: usize,
        optimizer: &str,
        accel: Accel,
        seed: u64,
    ) -> Result<StandardTrainer> {
        let plan = Plan::from_graph(graph)?;
        if batch == 0 {
            bail!("batch must be positive");
        }
        let micro = if microbatch == 0 { batch } else { microbatch };
        if batch % micro != 0 {
            bail!("microbatch {micro} must divide batch {batch}");
        }
        let mut rng = Pcg32::new(seed);
        let mut weights = Vec::new();
        let mut betas = Vec::new();
        let mut opt_w = Vec::new();
        let mut opt_b = Vec::new();
        let mut dw_acc = Vec::new();
        let mut dbeta_acc = Vec::new();
        for l in &plan.layers {
            let wl = l.weight_len();
            if wl == 0 {
                continue;
            }
            let w = glorot_init(&mut rng, l.fan_in(), l.channels(), wl);
            weights.push(Store::F32(w));
            betas.push(Store::F32(vec![0.0; l.channels()]));
            opt_w.push(OptState::new(optimizer, wl, false));
            opt_b.push(OptState::new(optimizer, l.channels(), false));
            dw_acc.push(vec![0.0; wl]);
            dbeta_acc.push(vec![0.0; l.channels()]);
        }
        let wcache = PackedWeightCache::new(weights.len());
        let sched = Arc::new(schedule::compile_step(
            &plan,
            "standard",
            accel == Accel::Naive,
            micro,
            batch / micro,
        )?);
        let mut ctx = StepCtx::default();
        ctx.arena.install(&sched.slots);
        Ok(StandardTrainer {
            plan,
            batch,
            micro,
            accel,
            weights,
            betas,
            opt_w,
            opt_b,
            acts: Vec::new(),
            pool_masks: Vec::new(),
            bn_mu: Vec::new(),
            bn_psi: Vec::new(),
            dw_acc,
            dbeta_acc,
            wcache,
            sched,
            ctx,
        })
    }

    /// The compiled schedule this engine executes.
    pub fn schedule(&self) -> &Arc<StepSchedule> {
        &self.sched
    }

    /// Swap in an externally compiled schedule (e.g. one
    /// deserialized from JSON) and reinstall the arena slots.  The
    /// schedule must have been compiled for the same plan / algo /
    /// tier / microbatch — execution asserts every event, so a
    /// mismatch fails fast rather than corrupting.
    pub fn install_schedule(&mut self, sched: Arc<StepSchedule>) {
        self.ctx.arena.install(&sched.slots);
        self.sched = sched;
    }

    /// Total weight packs so far (the once-per-step probe).
    pub fn weight_pack_count(&self) -> usize {
        self.wcache.pack_count()
    }

    fn chunks(&self) -> usize {
        self.batch / self.micro
    }

    /// GEMM dispatch honoring the accel mode.
    fn gemm(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        self.accel.backend().gemm_f32(m, k, n, a, b, out);
    }

    fn beta_f32(&self, wi: usize) -> &[f32] {
        self.betas[wi].as_f32().expect("standard engine stores f32 betas")
    }

    /// Binarized weights Ŵ (k×n, ±1 f32) unpacked from the per-step
    /// cache into a caller-owned buffer — packed once per step, no
    /// per-use allocation.
    fn signed_w_into(&mut self, wi: usize, k: usize, n: usize, out: &mut [f32]) {
        let weights = &self.weights;
        let w = self.wcache.w(wi, |dst| {
            BitMatrix::pack_into(k, n, weights[wi].as_f32().expect("f32 weights"), dst)
        });
        w.unpack_into(out);
    }

    /// Binarized transposed weights Ŵᵀ (n×k, ±1 f32): derived from
    /// the cached Ŵ by the word-level block transpose, unpacked into
    /// a caller-owned buffer.
    fn signed_wt_into(&mut self, wi: usize, k: usize, n: usize, out: &mut [f32]) {
        let weights = &self.weights;
        let wt = self.wcache.wt_via_transpose(wi, |dst| {
            BitMatrix::pack_into(k, n, weights[wi].as_f32().expect("f32 weights"), dst)
        });
        wt.unpack_into(out);
    }

    /// Drain any retained chunk state back to the arena (begin-step
    /// hygiene after an aborted step, and the end-of-chunk drain).
    fn drain_chunk_state(&mut self) {
        for v in self.acts.drain(..) {
            self.ctx.arena.put_f32(v);
        }
        for v in self.bn_mu.drain(..).chain(self.bn_psi.drain(..)) {
            self.ctx.arena.put_f32(v);
        }
        for m in self.pool_masks.drain(..) {
            self.ctx.arena.put_u32(m);
        }
    }

    fn begin_step(&mut self) {
        self.drain_chunk_state();
        self.ctx.drain_skip_stacks();
        for dw in self.dw_acc.iter_mut() {
            dw.fill(0.0);
        }
        for db in self.dbeta_acc.iter_mut() {
            db.fill(0.0);
        }
    }

    /// Deferred optimizer update: consume the step's accumulated
    /// ∂W/∂β once, after the last chunk.  Equivalent to the old
    /// per-layer in-backward updates (weights are not read again
    /// after their own dX matmul within a step).
    fn apply_update(&mut self, lr: f32) {
        for st in self.opt_w.iter_mut().chain(self.opt_b.iter_mut()) {
            st.tick();
        }
        for wi in 0..self.weights.len() {
            cancel_wgrad(&mut self.dw_acc[wi], &self.weights[wi]);
            self.opt_w[wi].update(&mut self.weights[wi], &self.dw_acc[wi], lr, true);
            self.opt_b[wi].update(&mut self.betas[wi], &self.dbeta_acc[wi], lr, false);
        }
        self.wcache.invalidate_all();
    }
}

impl EngineOps for StandardTrainer {
    type Grad = Vec<f32>;

    fn micro(&self) -> usize {
        self.micro
    }

    fn ctx(&mut self) -> &mut StepCtx {
        &mut self.ctx
    }

    fn grad_to_f32(&mut self, g: Vec<f32>) -> Vec<f32> {
        g
    }

    fn grad_from_f32(&mut self, v: Vec<f32>) -> Vec<f32> {
        v
    }

    fn recycle_grad(&mut self, g: Vec<f32>) {
        self.ctx.arena.put_f32(g);
    }

    fn matmul_forward(
        &mut self,
        cur: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        retain: bool,
    ) -> Result<Vec<f32>> {
        let b = self.micro;
        let (y, rows, n) = match *layer {
            LayerPlan::Dense { k, n, first } => {
                let mut y = self.ctx.arena.take_f32(b * n);
                if first || self.accel == Accel::Naive {
                    // f32 GEMM over the binarized operands
                    let mut bw = self.ctx.arena.take_f32(k * n);
                    self.signed_w_into(wi, k, n, &mut bw);
                    if first {
                        self.gemm(b, k, n, &cur, &bw, &mut y);
                    } else {
                        let mut a = self.ctx.arena.take_f32(cur.len());
                        sign_into(&cur, &mut a);
                        self.gemm(b, k, n, &a, &bw, &mut y);
                        self.ctx.arena.put_f32(a);
                    }
                    self.ctx.arena.put_f32(bw);
                } else {
                    // binary×binary hidden fc: pack X̂ and run the
                    // XNOR-popcount path against the cached packed Ŵᵀ
                    // — no padding, so no sign correction is needed
                    // and the result is the exact ±1 dot product
                    let mut xhat = self.ctx.arena.take_bits(b, k);
                    BitMatrix::pack_into(b, k, &cur, &mut xhat);
                    let backend = self.accel.backend();
                    let weights = &self.weights;
                    let (wt, bp) = self.wcache.wt_via_transpose_with_panels(wi, |dst| {
                        BitMatrix::pack_into(k, n, weights[wi].as_f32().unwrap(), dst)
                    });
                    backend.xnor_gemm_packed(&xhat, wt, bp, &mut y);
                    self.ctx.arena.put_bits(xhat);
                }
                (y, b, n)
            }
            LayerPlan::Conv { g, cout, first } => {
                let rows = g.rows(b);
                let mut y;
                if first || self.accel == Accel::Naive {
                    let mut bw = self.ctx.arena.take_f32(g.k() * cout);
                    self.signed_w_into(wi, g.k(), cout, &mut bw);
                    if self.accel == Accel::Naive {
                        // direct loops, minimal buffers
                        y = self.ctx.arena.take_zeroed_f32(rows * cout);
                        if first {
                            conv_direct_into(&cur, &bw, b, g, cout, &mut y);
                        } else {
                            let mut a = self.ctx.arena.take_f32(cur.len());
                            sign_into(&cur, &mut a);
                            conv_direct_into(&a, &bw, b, g, cout, &mut y);
                            self.ctx.arena.put_f32(a);
                        }
                    } else {
                        // real-input first layer on the accelerated
                        // tiers: tap-streamed f32 im2col — one
                        // rows×cin panel instead of the rows×k cols
                        // buffer, bit-identical to the unfused GEMM
                        y = self.ctx.arena.take_f32(rows * cout);
                        let mut panel = self.ctx.arena.take_f32(rows * g.cin);
                        let backend = self.accel.backend();
                        conv_fwd_first_streaming_into(
                            &cur, &bw, b, g, cout, backend, &mut y, &mut panel,
                        );
                        self.ctx.arena.put_f32(panel);
                    }
                    self.ctx.arena.put_f32(bw);
                } else {
                    // fused binary path: patches signed+packed
                    // straight into row panels (no f32 cols, no
                    // sign copy), XNOR against the cached packed
                    // Ŵᵀ, then the masked padding edge correction
                    // back to zero-pad semantics (no-op for VALID)
                    y = self.ctx.arena.take_f32(rows * cout);
                    let backend = self.accel.backend();
                    let mut xhat = self.ctx.arena.take_bits(rows, g.k());
                    im2col_packed_into(&cur, b, g, &backend.pool(), &mut xhat);
                    let weights = &self.weights;
                    let (wt, bp) = self.wcache.wt_via_transpose_with_panels(wi, |dst| {
                        BitMatrix::pack_into(g.k(), cout, weights[wi].as_f32().unwrap(), dst)
                    });
                    backend.xnor_gemm_packed(&xhat, wt, bp, &mut y);
                    let mut scratch = self.ctx.arena.take_f32(g.kside * g.kside * cout);
                    subtract_pad_contrib_with(&mut y, wt, b, g, &mut scratch);
                    self.ctx.arena.put_f32(scratch);
                    self.ctx.arena.put_bits(xhat);
                }
                (y, rows, cout)
            }
            _ => unreachable!("matmul_forward on a non-matmul layer"),
        };
        let mut xn = self.ctx.arena.take_f32(rows * n);
        let mut mu = self.ctx.arena.take_f32(n);
        let mut psi = self.ctx.arena.take_f32(n);
        bn_l2_forward_into(&y, rows, n, self.beta_f32(wi), &mut xn, &mut mu, &mut psi);
        self.ctx.arena.put_f32(y);
        if retain {
            self.acts.push(cur); // retained X_l (f32!) at 2·wi
            self.bn_mu.push(mu);
            self.bn_psi.push(psi);
            let keep = self.ctx.arena.take_copy_f32(&xn);
            self.acts.push(keep); // x_{l+1} retained at 2·wi + 1
        } else {
            self.ctx.arena.put_f32(cur);
            self.ctx.arena.put_f32(mu);
            self.ctx.arena.put_f32(psi);
        }
        Ok(xn)
    }

    fn matmul_backward(
        &mut self,
        dnext: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
    ) -> Result<Vec<f32>> {
        let b = self.micro;
        let direct = self.chunks() == 1; // write ∂W straight into the accumulator
        let (rows, n) = match *layer {
            LayerPlan::Dense { n, .. } => (b, n),
            LayerPlan::Conv { g, cout, .. } => (g.rows(b), cout),
            _ => unreachable!("matmul_backward on a non-matmul layer"),
        };
        // BN backward: dY from ∂x_{l+1}; ∂β adds into the step
        // accumulator
        let mut dy = self.ctx.arena.take_f32(rows * n);
        {
            let mut mv = self.ctx.arena.take_f32(n);
            let mut mvx = self.ctx.arena.take_f32(n);
            bn_l2_backward_into(
                &dnext,
                &self.acts[2 * wi + 1],
                self.betas[wi].as_f32().expect("f32 betas"),
                &self.bn_psi[wi],
                rows,
                n,
                &mut dy,
                &mut self.dbeta_acc[wi],
                &mut mv,
                &mut mvx,
            );
            self.ctx.arena.put_f32(mv);
            self.ctx.arena.put_f32(mvx);
        }
        self.ctx.arena.put_f32(dnext);

        let dx_out = match *layer {
            LayerPlan::Dense { k, n, first } => {
                let dx_out = if first {
                    Vec::new()
                } else {
                    // dX = dY @ Ŵᵀ (from the per-step cache)
                    let mut wt_f = self.ctx.arena.take_f32(n * k);
                    self.signed_wt_into(wi, k, n, &mut wt_f);
                    let mut dx = self.ctx.arena.take_f32(rows * k);
                    self.gemm(rows, n, k, &dy, &wt_f, &mut dx);
                    self.ctx.arena.put_f32(wt_f);
                    ste_mask_apply(&mut dx, &self.acts[2 * wi]);
                    dx
                };
                // dW = X̂ᵀ·dY — transpose-free; on the accelerated
                // tiers contracted straight off the packed bit panel.
                // Accumulates into dw_acc (directly when this is the
                // only chunk, else via an arena scratch + add); the
                // first/naive/packed dispatch is shared between both
                // arms via `dense_dw_into` so it cannot diverge.
                let backend = self.accel.backend();
                let naive = self.accel == Accel::Naive;
                if direct {
                    dense_dw_into(
                        backend,
                        naive,
                        &self.acts[2 * wi],
                        &dy,
                        rows,
                        k,
                        n,
                        first,
                        &mut self.ctx.arena,
                        &mut self.dw_acc[wi],
                    );
                } else {
                    let mut dw = self.ctx.arena.take_f32(k * n);
                    dense_dw_into(
                        backend,
                        naive,
                        &self.acts[2 * wi],
                        &dy,
                        rows,
                        k,
                        n,
                        first,
                        &mut self.ctx.arena,
                        &mut dw,
                    );
                    simd::add_assign_f32(&mut self.dw_acc[wi], &dw);
                    self.ctx.arena.put_f32(dw);
                }
                dx_out
            }
            LayerPlan::Conv { g, cout, first } => {
                let k = g.k();
                let fused = !first && self.accel != Accel::Naive;
                let dx_out = if first {
                    Vec::new()
                } else if fused {
                    // fused backward: dX streams per-tap panels of
                    // dY·Ŵᵀ straight into the map — no rows×k dcols,
                    // no full f32 Ŵᵀ unpack
                    let backend = self.accel.backend();
                    let mut dx = self.ctx.arena.take_zeroed_f32(g.in_len(b));
                    let mut panel = self.ctx.arena.take_f32(rows * g.cin);
                    let mut wtap = self.ctx.arena.take_f32(cout * g.cin);
                    {
                        let weights = &self.weights;
                        let wt = self.wcache.wt_via_transpose(wi, |dst| {
                            BitMatrix::pack_into(k, cout, weights[wi].as_f32().unwrap(), dst)
                        });
                        conv_dx_streaming_into(
                            &dy, wt, b, g, backend, &mut dx, &mut panel, &mut wtap,
                        );
                    }
                    self.ctx.arena.put_f32(panel);
                    self.ctx.arena.put_f32(wtap);
                    ste_mask_apply(&mut dx, &self.acts[2 * wi]);
                    dx
                } else {
                    // reference path (naive accel): f32 im2col math,
                    // buffers arena-scoped to die as soon as consumed
                    let mut wt_f = self.ctx.arena.take_f32(cout * k);
                    self.signed_wt_into(wi, k, cout, &mut wt_f);
                    let mut dcols = self.ctx.arena.take_f32(rows * k);
                    self.gemm(rows, cout, k, &dy, &wt_f, &mut dcols);
                    self.ctx.arena.put_f32(wt_f);
                    let mut dx = self.ctx.arena.take_zeroed_f32(g.in_len(b));
                    col2im_into(&dcols, b, g, &mut dx);
                    self.ctx.arena.put_f32(dcols);
                    ste_mask_apply(&mut dx, &self.acts[2 * wi]);
                    dx
                };
                // dW accumulation — fused/reference dispatch shared
                // between the direct and accumulate arms via
                // `conv_dw_into` so it cannot diverge
                let backend = self.accel.backend();
                if direct {
                    conv_dw_into(
                        backend,
                        fused,
                        &self.acts[2 * wi],
                        &dy,
                        b,
                        g,
                        cout,
                        first,
                        &mut self.ctx.arena,
                        &mut self.dw_acc[wi],
                    );
                } else {
                    let mut dw = self.ctx.arena.take_f32(k * cout);
                    conv_dw_into(
                        backend,
                        fused,
                        &self.acts[2 * wi],
                        &dy,
                        b,
                        g,
                        cout,
                        first,
                        &mut self.ctx.arena,
                        &mut dw,
                    );
                    simd::add_assign_f32(&mut self.dw_acc[wi], &dw);
                    self.ctx.arena.put_f32(dw);
                }
                dx_out
            }
            _ => unreachable!(),
        };
        self.ctx.arena.put_f32(dy);
        Ok(dx_out)
    }

    fn pool_forward(
        &mut self,
        cur: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
        retain: bool,
    ) -> Vec<f32> {
        let b = self.micro;
        let (oh, ow) = pool_out_dims(h, w, kside, stride);
        let cells = b * oh * ow * c;
        let mut out = self.ctx.arena.take_f32(cells);
        let mut mask = self.ctx.arena.take_u32(cells);
        maxpool_forward_into(&cur, b, h, w, c, kside, stride, &mut out, &mut mask);
        self.ctx.arena.put_f32(cur);
        if retain {
            self.pool_masks.push(mask);
        } else {
            self.ctx.arena.put_u32(mask);
        }
        out
    }

    fn pool_backward(
        &mut self,
        dnext: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
    ) -> Vec<f32> {
        let b = self.micro;
        let mask = self.pool_masks.pop().expect("pool mask stack underflow");
        let mut dx = self.ctx.arena.take_zeroed_f32(b * h * w * c);
        maxpool_backward_into(&dnext, &mask, b, h, w, c, kside, stride, &mut dx);
        self.ctx.arena.put_u32(mask);
        self.ctx.arena.put_f32(dnext);
        dx
    }

    fn end_chunk(&mut self) {
        self.drain_chunk_state();
    }
}

impl StepEngine for StandardTrainer {
    fn train_step(&mut self, x: &[f32], labels: &[usize], lr: f32) -> Result<(f32, f32)> {
        if x.len() != self.batch * self.plan.input_elems || labels.len() != self.batch {
            bail!("bad batch shapes");
        }
        self.begin_step();
        let sched = self.sched.clone();
        self.ctx.arena.begin_pass(sched.train_pass().clone());
        let r = ops::run_train_chunks(self, &sched, x, labels);
        let (loss, acc) = match r {
            Ok(v) => v,
            Err(e) => {
                self.ctx.arena.abort_pass();
                return Err(e);
            }
        };
        self.ctx.arena.end_pass();
        self.apply_update(lr);
        Ok((loss, acc))
    }

    fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)> {
        if x.len() != self.batch * self.plan.input_elems || labels.len() != self.batch {
            bail!("bad batch shapes");
        }
        self.drain_chunk_state();
        self.ctx.drain_skip_stacks();
        let sched = self.sched.clone();
        self.ctx.arena.begin_pass(sched.eval_pass().clone());
        let r = ops::run_eval_chunks(self, &sched, x, labels);
        match r {
            Ok(v) => {
                self.ctx.arena.end_pass();
                Ok(v)
            }
            Err(e) => {
                self.ctx.arena.abort_pass();
                Err(e)
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.weights.iter().map(Store::heap_bytes).sum::<usize>()
            + self.betas.iter().map(Store::heap_bytes).sum::<usize>()
            + self.opt_w.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.opt_b.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.dw_acc.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.dbeta_acc.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.wcache.heap_bytes()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn microbatch(&self) -> usize {
        self.micro
    }

    fn arena_bytes(&self) -> usize {
        self.ctx.arena.heap_bytes()
    }

    fn weights_snapshot(&self) -> Vec<Vec<f32>> {
        // interleaved [w0, beta0, ...] — see ProposedTrainer
        let mut out = Vec::with_capacity(self.weights.len() * 2);
        for (w, b) in self.weights.iter().zip(&self.betas) {
            out.push(w.to_f32());
            out.push(b.to_f32());
        }
        out
    }

    fn load_weights(&mut self, w: &[Vec<f32>]) -> Result<()> {
        if w.len() != self.weights.len() * 2 {
            bail!("snapshot layer count mismatch");
        }
        for (i, chunk) in w.chunks(2).enumerate() {
            if chunk[0].len() != self.weights[i].len()
                || chunk[1].len() != self.betas[i].len()
            {
                bail!("snapshot shape mismatch at layer {i}");
            }
            self.weights[i] = Store::F32(chunk[0].clone());
            self.betas[i] = Store::F32(chunk[1].clone());
        }
        self.wcache.invalidate_all();
        Ok(())
    }

    fn arena_idle(&self) -> bool {
        self.ctx.arena.idle()
    }
}

/// Dense dW contraction X̂ᵀ·dY into `dst` (the step accumulator or an
/// arena scratch, fully overwritten): f32 AᵀB for the real-input
/// first layer, sign-copy reference on the naive tier, straight off
/// the packed bit panel otherwise.  One function for both
/// accumulation arms of `matmul_backward`, so the dispatch cannot
/// diverge between them.
#[allow(clippy::too_many_arguments)]
fn dense_dw_into(
    backend: crate::bitops::Backend,
    naive: bool,
    xin: &[f32],
    dy: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    first: bool,
    arena: &mut StepArena,
    dst: &mut [f32],
) {
    if first {
        backend.gemm_f32_at(rows, k, n, xin, dy, dst);
    } else if naive {
        let mut xs = arena.take_f32(xin.len());
        sign_into(xin, &mut xs);
        backend.gemm_f32_at(rows, k, n, &xs, dy, dst);
        arena.put_f32(xs);
    } else {
        let mut xhat = arena.take_bits(rows, k);
        BitMatrix::pack_into(rows, k, xin, &mut xhat);
        backend.packed_at_gemm_f32(&xhat, dy, n, dst);
        arena.put_bits(xhat);
    }
}

/// Conv dW contraction into `dst` (fully overwritten): the fused path
/// re-runs the bit-im2col on the retained f32 acts, contracts off the
/// packed panel and restores zero-pad dW semantics; the reference
/// path is the zero-pad f32 im2col + transpose-free AᵀB GEMM.  Shared
/// by both accumulation arms of `matmul_backward`.
#[allow(clippy::too_many_arguments)]
fn conv_dw_into(
    backend: crate::bitops::Backend,
    fused: bool,
    xin: &[f32],
    dy: &[f32],
    b: usize,
    g: ConvGeom,
    cout: usize,
    first: bool,
    arena: &mut StepArena,
    dst: &mut [f32],
) {
    let k = g.k();
    let rows = g.rows(b);
    if fused {
        let mut xh = arena.take_bits(rows, k);
        im2col_packed_into(xin, b, g, &backend.pool(), &mut xh);
        let mut scratch = arena.take_f32(g.kside * g.kside * cout);
        backend.packed_at_gemm_f32(&xh, dy, cout, dst);
        subtract_pad_dw_contrib_with(dst, dy, b, g, cout, &mut scratch);
        arena.put_f32(scratch);
        arena.put_bits(xh);
    } else if first {
        // fused first-layer dW: tap-streamed panels contract straight
        // into each tap's dW rows — the backward twin of the fused
        // first-conv forward, no rows×k cols, bit-identical to the
        // unfused AᵀB on every tier
        let mut panel = arena.take_f32(rows * g.cin);
        conv_dw_first_streaming_into(xin, dy, b, g, cout, backend, dst, &mut panel);
        arena.put_f32(panel);
    } else {
        let mut cols = arena.take_zeroed_f32(rows * k);
        let mut xs = arena.take_f32(xin.len());
        sign_into(xin, &mut xs);
        im2col_into(&xs, b, g, &mut cols);
        arena.put_f32(xs);
        backend.gemm_f32_at(rows, k, cout, &cols, dy, dst);
        arena.put_f32(cols);
    }
}

// ----------------------------------------------------- shared helpers
// (pub(crate): the proposed engine reuses the float kernels)

/// Binarize into a caller-owned buffer (every cell written):
/// sgn(x) with sgn(0) = +1.
pub(crate) fn sign_into(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = if v >= 0.0 { 1.0 } else { -1.0 };
    }
}

pub fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = a[r * cols + c];
        }
    }
    t
}

/// STE gradient cancellation: dx ⊙ 1{|x| ≤ 1}.
pub(crate) fn ste_mask_apply(dx: &mut [f32], x: &[f32]) {
    for (d, &v) in dx.iter_mut().zip(x) {
        if v.abs() > 1.0 {
            *d = 0.0;
        }
    }
}

/// Weight gradient cancellation (Courbariaux): zero where |w| > 1.
pub(crate) fn cancel_wgrad(dw: &mut [f32], w: &Store) {
    for (i, d) in dw.iter_mut().enumerate() {
        if w.get(i).abs() > 1.0 {
            *d = 0.0;
        }
    }
}

/// ℓ2 batch norm forward over (rows × channels): Alg. 1 lines 5-7.
/// (Allocating test convenience; the engines use the `_into` form.)
#[cfg(test)]
pub(crate) fn bn_l2_forward(
    y: &[f32],
    rows: usize,
    channels: usize,
    beta: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut xn = vec![0.0f32; y.len()];
    let mut mu = vec![0.0f32; channels];
    let mut psi = vec![0.0f32; channels];
    bn_l2_forward_into(y, rows, channels, beta, &mut xn, &mut mu, &mut psi);
    (xn, mu, psi)
}

/// [`bn_l2_forward`] into caller-owned buffers (all re-zeroed here;
/// recycled dirty storage fine).
pub(crate) fn bn_l2_forward_into(
    y: &[f32],
    rows: usize,
    channels: usize,
    beta: &[f32],
    xn: &mut [f32],
    mu: &mut [f32],
    psi: &mut [f32],
) {
    debug_assert_eq!(y.len(), rows * channels);
    debug_assert_eq!(xn.len(), y.len());
    mu.fill(0.0);
    psi.fill(0.0);
    for r in 0..rows {
        for c in 0..channels {
            mu[c] += y[r * channels + c];
        }
    }
    for m in mu.iter_mut() {
        *m /= rows as f32;
    }
    for r in 0..rows {
        for c in 0..channels {
            let d = y[r * channels + c] - mu[c];
            psi[c] += d * d;
        }
    }
    for p in psi.iter_mut() {
        *p = (*p / rows as f32 + 1e-5).sqrt();
    }
    for r in 0..rows {
        for c in 0..channels {
            xn[r * channels + c] = (y[r * channels + c] - mu[c]) / psi[c] + beta[c];
        }
    }
}

/// ℓ2 batch norm backward: Alg. 1 lines 10-13 (xn is x_{l+1}).
#[cfg(test)]
pub(crate) fn bn_l2_backward(
    dx: &[f32],
    x_next: &[f32],
    beta: &[f32],
    psi: &[f32],
    rows: usize,
    channels: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dy = vec![0.0f32; dx.len()];
    let mut dbeta = vec![0.0f32; channels];
    let mut mv = vec![0.0f32; channels];
    let mut mvx = vec![0.0f32; channels];
    bn_l2_backward_into(dx, x_next, beta, psi, rows, channels, &mut dy, &mut dbeta, &mut mv, &mut mvx);
    (dy, dbeta)
}

/// [`bn_l2_backward`] into caller-owned buffers.  `dy`, `mv`, `mvx`
/// are overwritten (dirty recycled storage fine); `dbeta_acc` is
/// **added into** — the microbatch accumulation point for ∂β.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bn_l2_backward_into(
    dx: &[f32],
    x_next: &[f32],
    beta: &[f32],
    psi: &[f32],
    rows: usize,
    channels: usize,
    dy: &mut [f32],
    dbeta_acc: &mut [f32],
    mv: &mut [f32],
    mvx: &mut [f32],
) {
    debug_assert_eq!(dx.len(), rows * channels);
    debug_assert_eq!(dy.len(), dx.len());
    mv.fill(0.0);
    mvx.fill(0.0);
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            let xn = x_next[r * channels + c] - beta[c];
            mv[c] += v;
            mvx[c] += v * xn;
            dbeta_acc[c] += dx[r * channels + c];
        }
    }
    for c in 0..channels {
        mv[c] /= rows as f32;
        mvx[c] /= rows as f32;
    }
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            let xn = x_next[r * channels + c] - beta[c];
            dy[r * channels + c] = v - mv[c] - mvx[c] * xn;
        }
    }
}

/// Output dims of a `kside`×`kside` stride-`stride` max-pool over an
/// `h × w` map (VALID floor geometry; plan building guarantees the
/// floor drops nothing).
pub fn pool_out_dims(h: usize, w: usize, kside: usize, stride: usize) -> (usize, usize) {
    ((h - kside) / stride + 1, (w - kside) / stride + 1)
}

#[cfg(test)]
pub(crate) fn maxpool_forward(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kside: usize,
    stride: usize,
) -> (Vec<f32>, Vec<u32>) {
    let (oh, ow) = pool_out_dims(h, w, kside, stride);
    let cells = b * oh * ow * c;
    let mut out = vec![0.0f32; cells];
    let mut mask = vec![0u32; cells];
    maxpool_forward_into(x, b, h, w, c, kside, stride, &mut out, &mut mask);
    (out, mask)
}

/// `kside`×`kside` stride-`stride` max-pool forward (NHWC) into
/// caller-owned buffers (every cell written).  `mask` records the
/// winner's in-window index
/// `ky·kside + kx` — for the classic 2×2 stride-2 pool this is the
/// historical `[(0,0),(0,1),(1,0),(1,1)]` encoding, bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_forward_into(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kside: usize,
    stride: usize,
    out: &mut [f32],
    mask: &mut [u32],
) {
    let (oh, ow) = pool_out_dims(h, w, kside, stride);
    debug_assert_eq!(x.len(), b * h * w * c);
    debug_assert_eq!(out.len(), b * oh * ow * c);
    debug_assert_eq!(mask.len(), out.len());
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u32;
                    for ky in 0..kside {
                        for kx in 0..kside {
                            let v = x
                                [((bi * h + oy * stride + ky) * w + ox * stride + kx) * c + ch];
                            if v > best {
                                best = v;
                                bidx = (ky * kside + kx) as u32;
                            }
                        }
                    }
                    let o = ((bi * oh + oy) * ow + ox) * c + ch;
                    out[o] = best;
                    mask[o] = bidx;
                }
            }
        }
    }
}

#[cfg(test)]
pub(crate) fn maxpool_backward(
    dout: &[f32],
    mask: &[u32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kside: usize,
    stride: usize,
) -> Vec<f32> {
    let mut dx = vec![0.0f32; b * h * w * c];
    maxpool_backward_into(dout, mask, b, h, w, c, kside, stride, &mut dx);
    dx
}

/// Max-pool backward (winner routing off the forward mask) into a
/// caller-owned buffer, which must be
/// **zeroed** (only winning cells are touched).  Overlapping windows
/// (stride < kside) accumulate — one input cell can win several
/// windows; non-overlapping geometry keeps the historical
/// single-write behaviour bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_backward_into(
    dout: &[f32],
    mask: &[u32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kside: usize,
    stride: usize,
    dx: &mut [f32],
) {
    let (oh, ow) = pool_out_dims(h, w, kside, stride);
    debug_assert_eq!(dx.len(), b * h * w * c);
    let overlap = stride < kside;
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let o = ((bi * oh + oy) * ow + ox) * c + ch;
                    let (ky, kx) = ((mask[o] as usize) / kside, (mask[o] as usize) % kside);
                    let ii = ((bi * h + oy * stride + ky) * w + ox * stride + kx) * c + ch;
                    if overlap {
                        dx[ii] += dout[o];
                    } else {
                        dx[ii] = dout[o];
                    }
                }
            }
        }
    }
}

/// im2col for any conv geometry, NHWC: output (B·OH·OW, k²·Cin).
/// The f32 reference the fused `bitops::im2col_packed` is bit-exact
/// against (and the pre-fusion baseline the conv bench diffs).
pub fn im2col(x: &[f32], b: usize, g: ConvGeom) -> Vec<f32> {
    let mut cols = vec![0.0f32; g.rows(b) * g.k()];
    im2col_into(x, b, g, &mut cols);
    cols
}

/// [`im2col`] into a caller-owned buffer, which must be **zeroed**
/// (SAME padding taps are left untouched as zeros).
pub fn im2col_into(x: &[f32], b: usize, g: ConvGeom, cols: &mut [f32]) {
    assert_eq!(x.len(), g.in_len(b), "NHWC shape mismatch");
    let k = g.k();
    assert_eq!(cols.len(), g.rows(b) * k, "cols shape mismatch");
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let mut idx = ((bi * g.oh + oy) * g.ow + ox) * k;
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if sy >= 0 && sy < g.h as isize && sx >= 0 && sx < g.w as isize {
                            let src = ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                            cols[idx..idx + g.cin].copy_from_slice(&x[src..src + g.cin]);
                        }
                        idx += g.cin;
                    }
                }
            }
        }
    }
}

/// col2im: scatter-add patch grads back to the input grad (any
/// geometry).  The f32 reference the streaming
/// `bitops::conv_dx_streaming` path is equivalent to (and the
/// pre-fusion baseline the backward bench runs).
pub fn col2im(dcols: &[f32], b: usize, g: ConvGeom) -> Vec<f32> {
    let mut dx = vec![0.0f32; g.in_len(b)];
    col2im_into(dcols, b, g, &mut dx);
    dx
}

/// [`col2im`] into a caller-owned buffer, which must be **zeroed**
/// (patch gradients scatter-add).
pub fn col2im_into(dcols: &[f32], b: usize, g: ConvGeom, dx: &mut [f32]) {
    let k = g.k();
    assert_eq!(dcols.len(), g.rows(b) * k, "cols shape mismatch");
    assert_eq!(dx.len(), g.in_len(b), "dX shape mismatch");
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let mut idx = ((bi * g.oh + oy) * g.ow + ox) * k;
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if sy >= 0 && sy < g.h as isize && sx >= 0 && sx < g.w as isize {
                            let dst = ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                            for ci in 0..g.cin {
                                dx[dst + ci] += dcols[idx + ci];
                            }
                        }
                        idx += g.cin;
                    }
                }
            }
        }
    }
}

/// Direct convolution for any geometry (naïve mode: no im2col buffer).
#[cfg(test)]
pub(crate) fn conv_direct(
    x: &[f32],
    wgt: &[f32], // (k², cin, cout) flattened as kside*kside*cin rows × cout
    b: usize,
    g: ConvGeom,
    cout: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; g.rows(b) * cout];
    conv_direct_into(x, wgt, b, g, cout, &mut y);
    y
}

/// [`conv_direct`] into a caller-owned buffer, which must be
/// **zeroed** (taps accumulate).
pub(crate) fn conv_direct_into(
    x: &[f32],
    wgt: &[f32],
    b: usize,
    g: ConvGeom,
    cout: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), g.rows(b) * cout);
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let orow = ((bi * g.oh + oy) * g.ow + ox) * cout;
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    if sy < 0 || sy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if sx < 0 || sx >= g.w as isize {
                            continue;
                        }
                        let xrow = ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                        let wrow = (ky * g.kside + kx) * g.cin;
                        for ci in 0..g.cin {
                            let xv = x[xrow + ci];
                            let wr = (wrow + ci) * cout;
                            for co in 0..cout {
                                y[orow + co] += xv * wgt[wr + co];
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::gemm::gemm_f32;
    use crate::models::{get, lower, LayerSpec, ModelSpec};

    fn make(model: &str, batch: usize, accel: Accel) -> StandardTrainer {
        let g = lower(&get(model).unwrap()).unwrap();
        StandardTrainer::new(&g, batch, "adam", accel, 42).unwrap()
    }

    fn toy_batch(n: usize, k: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
        let mut g = Pcg32::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes).map(|_| g.normal_vec(k)).collect();
        let mut x = Vec::with_capacity(n * k);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            for j in 0..k {
                x.push(protos[c][j] + 0.3 * g.normal());
            }
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn mlp_mini_learns() {
        let mut t = make("mlp_mini", 32, Accel::Blocked);
        let (x, y) = toy_batch(32, 64, 10, 1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    fn conv_net_learns() {
        let mut t = make("cnv_mini", 16, Accel::Blocked);
        let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.75, "{first:?} -> {last}");
    }

    #[test]
    fn residual_net_learns() {
        // resnete_mini: stem conv + 4 skip blocks (one channel-doubling)
        let mut t = make("resnete_mini", 16, Accel::Blocked);
        let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 12);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last.is_finite());
        assert!(last < first.unwrap(), "{first:?} -> {last}");
    }

    #[test]
    fn naive_and_blocked_agree() {
        let mut a = make("mlp_mini", 8, Accel::Naive);
        let mut b = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 3);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-4, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tiled_matches_blocked_exactly() {
        // tiled re-bands the same kernels (and both fuse the binary
        // conv path identically), so runs are identical — conv and
        // residual models exercise the bit-im2col + pad-correction +
        // skip pipeline
        for (model, batch, k) in [
            ("mlp_mini", 8, 64),
            ("cnv_mini", 4, 16 * 16 * 3),
            ("bireal_mini", 4, 16 * 16 * 3),
        ] {
            let mut a = make(model, batch, Accel::Blocked);
            let mut b = make(model, batch, Accel::Tiled(2));
            let (x, y) = toy_batch(batch, k, 10, 3);
            for step in 0..3 {
                let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
                let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
                assert!((la - lb).abs() < 1e-6, "{model} step {step}: {la} vs {lb}");
            }
            for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
                assert_eq!(wa, wb, "{model}");
            }
        }
    }

    #[test]
    fn fused_conv_matches_naive_direct() {
        // the fused XNOR conv (+1-packed pads + masked edge
        // correction) against conv_direct's true zero padding: same
        // zero-pad semantics, so whole conv training runs agree
        let mut a = make("cnv_mini", 4, Accel::Naive);
        let mut b = make("cnv_mini", 4, Accel::Blocked);
        let (x, y) = toy_batch(4, 16 * 16 * 3, 10, 6);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-3, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn strided_and_valid_convs_train() {
        // strided SAME + VALID convs end to end on the accelerated
        // tiers, agreeing with the naive direct-conv reference
        let spec = ModelSpec {
            name: "strided_valid".into(),
            input_shape: vec![12, 12, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv_s(6, 3, 2).as_first(), // 12 -> 6 SAME s2
                LayerSpec::conv(8, 3).valid(),         // 6 -> 4 VALID
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let graph = lower(&spec).unwrap();
        let (x, y) = toy_batch(4, 12 * 12 * 3, 10, 9);
        let mut a = StandardTrainer::new(&graph, 4, "sgd", Accel::Naive, 5).unwrap();
        let mut b = StandardTrainer::new(&graph, 4, "sgd", Accel::Tiled(2), 5).unwrap();
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-3, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn weights_packed_at_most_once_per_step() {
        let mut t = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 9);
        t.train_step(&x, &y, 0.01).unwrap();
        let per_step = t.weight_pack_count();
        // one pack per weight layer per step: forward packs Ŵ, the
        // backward Ŵᵀ is a transpose of the cache, not a new pack
        assert!(per_step >= 1 && per_step <= t.weights.len(), "{per_step}");
        t.train_step(&x, &y, 0.01).unwrap();
        assert_eq!(t.weight_pack_count(), 2 * per_step);
    }

    #[test]
    fn microbatch_full_chunk_is_identical() {
        // micro == batch runs the very same code path values: losses
        // and weights must be bit-identical to the default trainer
        let g = lower(&get("cnv_mini").unwrap()).unwrap();
        let (x, y) = toy_batch(8, 16 * 16 * 3, 10, 21);
        let mut a = StandardTrainer::new(&g, 8, "adam", Accel::Blocked, 3).unwrap();
        let mut b =
            StandardTrainer::with_microbatch(&g, 8, 8, "adam", Accel::Blocked, 3).unwrap();
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(la, lb, "step {step}");
        }
        assert_eq!(a.weights_snapshot(), b.weights_snapshot());
    }

    #[test]
    fn steady_state_stops_allocating_from_the_arena() {
        // the installed slot table is the arena: its footprint is
        // fixed from construction and steps never move it (the hard
        // zero-alloc assert lives in rust/tests/memtrack_step.rs)
        for accel in [Accel::Blocked, Accel::Tiled(2)] {
            let mut t = make("cnv_mini", 4, accel);
            let (x, y) = toy_batch(4, 16 * 16 * 3, 10, 23);
            let bytes = t.ctx.arena.heap_bytes();
            assert_eq!(bytes, t.sched.arena_bytes(), "{accel:?}: install != schedule");
            for _ in 0..5 {
                t.train_step(&x, &y, 0.01).unwrap();
            }
            assert_eq!(t.ctx.arena.heap_bytes(), bytes, "{accel:?}: arena grew");
        }
    }

    #[test]
    fn conv_direct_matches_im2col_gemm() {
        let mut rng = Pcg32::new(4);
        for g in [
            ConvGeom::same1(5, 5, 3, 3),
            ConvGeom::same(8, 8, 3, 3, 2),
            ConvGeom::valid(7, 7, 2, 3, 1),
            ConvGeom::valid(9, 9, 2, 3, 2),
        ] {
            let b = 2;
            let cout = 4;
            let x = rng.normal_vec(g.in_len(b));
            let wg = rng.normal_vec(g.k() * cout);
            let direct = conv_direct(&x, &wg, b, g, cout);
            let cols = im2col(&x, b, g);
            let mut gemm_out = vec![0.0f32; g.rows(b) * cout];
            gemm_f32(g.rows(b), g.k(), cout, &cols, &wg, &mut gemm_out);
            for i in 0..direct.len() {
                assert!((direct[i] - gemm_out[i]).abs() < 1e-4, "{g:?} @ {i}");
            }
        }
    }

    #[test]
    fn col2im_adjoint_of_im2col() {
        // <im2col(x), c> == <x, col2im(c)> (adjointness), any geometry
        let mut rng = Pcg32::new(5);
        for g in [
            ConvGeom::same1(4, 4, 2, 3),
            ConvGeom::same(7, 7, 2, 3, 2),
            ConvGeom::valid(6, 6, 3, 3, 1),
        ] {
            let b = 1;
            let x = rng.normal_vec(g.in_len(b));
            let cvec = rng.normal_vec(g.rows(b) * g.k());
            let cx = im2col(&x, b, g);
            let ic: f32 = cx.iter().zip(&cvec).map(|(a, b)| a * b).sum();
            let xc = col2im(&cvec, b, g);
            let ci: f32 = x.iter().zip(&xc).map(|(a, b)| a * b).sum();
            assert!((ic - ci).abs() < 1e-3, "{g:?}: {ic} vs {ci}");
        }
    }

    #[test]
    fn maxpool_roundtrip() {
        let x = vec![
            1.0, 5.0, 2.0, 0.0, //
            3.0, 4.0, 8.0, 1.0, //
            0.0, 2.0, 1.0, 1.0, //
            9.0, 1.0, 0.0, 3.0,
        ];
        let (out, mask) = maxpool_forward(&x, 1, 4, 4, 1, 2, 2);
        assert_eq!(out, vec![5.0, 8.0, 9.0, 3.0]);
        let dx = maxpool_backward(&[1.0, 2.0, 3.0, 4.0], &mask, 1, 4, 4, 1, 2, 2);
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), 4);
        assert_eq!(dx[1], 1.0); // the 5.0 cell
        assert_eq!(dx[12], 3.0); // the 9.0 cell
    }

    #[test]
    fn maxpool_general_geometry() {
        // 5×5 map, 3×3 stride-2 pool → 2×2 output.
        let x: Vec<f32> = (0..25).map(|i| ((i * 7) % 13) as f32).collect();
        let (out, mask) = maxpool_forward(&x, 1, 5, 5, 1, 3, 2);
        assert_eq!(out.len(), 4);
        for (o, (oy, ox)) in [(0, 0), (0, 1), (1, 0), (1, 1)].iter().enumerate() {
            let mut best = f32::NEG_INFINITY;
            for ky in 0..3 {
                for kx in 0..3 {
                    best = best.max(x[(oy * 2 + ky) * 5 + ox * 2 + kx]);
                }
            }
            assert_eq!(out[o], best);
        }
        // Overlapping windows accumulate in the backward scatter.
        let dx = maxpool_backward(&[1.0, 1.0, 1.0, 1.0], &mask, 1, 5, 5, 1, 3, 2);
        assert_eq!(dx.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn bn_l2_normalizes() {
        let mut g = Pcg32::new(6);
        let rows = 64;
        let ch = 4;
        let y: Vec<f32> = g.normal_vec(rows * ch).iter().map(|v| v * 3.0 + 1.0).collect();
        let (xn, _, _) = bn_l2_forward(&y, rows, ch, &vec![0.0; ch]);
        for c in 0..ch {
            let m: f32 = (0..rows).map(|r| xn[r * ch + c]).sum::<f32>() / rows as f32;
            let v: f32 =
                (0..rows).map(|r| (xn[r * ch + c] - m).powi(2)).sum::<f32>() / rows as f32;
            assert!(m.abs() < 1e-4);
            assert!((v - 1.0).abs() < 0.05, "{v}");
        }
    }

    #[test]
    fn eval_does_not_mutate() {
        let mut t = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 7);
        let before = t.weights_snapshot();
        t.eval(&x, &y).unwrap();
        assert_eq!(before, t.weights_snapshot());
    }

    #[test]
    fn residual_eval_matches_train_forward_value() {
        // eval (retain = false) must still consume the skip buffers:
        // identical logits path to the training forward
        let mut t = make("resnete_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 16 * 16 * 3, 10, 13);
        let (le, _) = t.eval(&x, &y).unwrap();
        let (lt, _) = t.train_step(&x, &y, 0.0).unwrap();
        // lr = 0 still updates optimizer state but the forward ran on
        // the same weights — losses must agree exactly
        assert_eq!(le, lt);
    }

    #[test]
    fn snapshot_roundtrip() {
        let mut a = make("mlp_mini", 8, Accel::Blocked);
        let mut b = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 8);
        a.train_step(&x, &y, 0.01).unwrap();
        b.load_weights(&a.weights_snapshot()).unwrap();
        let (la, _) = a.eval(&x, &y).unwrap();
        let (lb, _) = b.eval(&x, &y).unwrap();
        assert!((la - lb).abs() < 1e-6);
    }
}
