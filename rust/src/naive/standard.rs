//! Algorithm 1 — Courbariaux & Bengio's standard BNN training step,
//! float32 everywhere, ℓ2 batch normalization.
//!
//! Memory behaviour is the point: every layer's input activations are
//! retained in f32 between forward and backward (Fig. 1's red
//! dependency), pool masks are f32-indexed, weights/momenta/grads are
//! f32 — exactly the left half of Table 2, so the tracking allocator
//! measures what the paper's standard prototype measured.

use anyhow::{bail, Result};

use super::plan::{LayerPlan, Plan};
use super::{glorot_init, softmax_xent_grad, Accel, StepEngine};
use crate::bitops::{
    conv_dx_streaming, im2col_packed, subtract_pad_contrib, subtract_pad_dw_contrib, BitMatrix,
    PackedWeightCache,
};
use crate::models::Graph;
use crate::optim::{OptState, Store};
use crate::util::rng::Pcg32;

pub struct StandardTrainer {
    plan: Plan,
    batch: usize,
    accel: Accel,
    // parameters (f32 latent weights, clipped to [-1,1]) + BN biases
    weights: Vec<Store>,
    betas: Vec<Store>,
    opt_w: Vec<OptState>,
    opt_b: Vec<OptState>,
    // retained per step (transient between fwd and bwd)
    acts: Vec<Vec<f32>>,       // f32 activations per layer boundary
    pool_masks: Vec<Vec<u32>>, // argmax index per pooled cell (f32-class storage)
    bn_mu: Vec<Vec<f32>>,
    bn_psi: Vec<Vec<f32>>,
    /// Per-step binarized-weight cache: sign(W) is packed once per
    /// step and unpacked per use; invalidated on weight update.
    wcache: PackedWeightCache,
}

impl StandardTrainer {
    pub fn new(
        graph: &Graph,
        batch: usize,
        optimizer: &str,
        accel: Accel,
        seed: u64,
    ) -> Result<StandardTrainer> {
        let plan = Plan::from_graph(graph)?;
        if batch == 0 {
            bail!("batch must be positive");
        }
        let mut rng = Pcg32::new(seed);
        let mut weights = Vec::new();
        let mut betas = Vec::new();
        let mut opt_w = Vec::new();
        let mut opt_b = Vec::new();
        for l in &plan.layers {
            let wl = l.weight_len();
            if wl == 0 {
                continue;
            }
            let w = glorot_init(&mut rng, l.fan_in(), l.channels(), wl);
            weights.push(Store::F32(w));
            betas.push(Store::F32(vec![0.0; l.channels()]));
            opt_w.push(OptState::new(optimizer, wl, false));
            opt_b.push(OptState::new(optimizer, l.channels(), false));
        }
        let wcache = PackedWeightCache::new(weights.len());
        Ok(StandardTrainer {
            plan,
            batch,
            accel,
            weights,
            betas,
            opt_w,
            opt_b,
            acts: Vec::new(),
            pool_masks: Vec::new(),
            bn_mu: Vec::new(),
            bn_psi: Vec::new(),
            wcache,
        })
    }

    /// Total weight packs so far (the once-per-step probe).
    pub fn weight_pack_count(&self) -> usize {
        self.wcache.pack_count()
    }

    /// GEMM dispatch honoring the accel mode.
    fn gemm(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        self.accel.backend().gemm_f32(m, k, n, a, b, out);
    }

    /// Binarized weights Ŵ (k×n, ±1 f32) via the per-step cache —
    /// packed once per step instead of sign_vec'd per matmul.
    fn signed_w(&mut self, wi: usize, k: usize, n: usize) -> Vec<f32> {
        let weights = &self.weights;
        self.wcache
            .w(wi, || BitMatrix::pack(k, n, &weights[wi].to_f32()))
            .unpack()
    }

    /// Binarized transposed weights Ŵᵀ (n×k, ±1 f32): derived from
    /// the cached Ŵ by the word-level block transpose.
    fn signed_wt(&mut self, wi: usize, k: usize, n: usize) -> Vec<f32> {
        let weights = &self.weights;
        self.wcache
            .wt_via_transpose(wi, || BitMatrix::pack(k, n, &weights[wi].to_f32()))
            .unpack()
    }

    /// Forward through all layers, retaining f32 activations; returns
    /// logits.  `retain` disables residual storage for eval.
    fn forward(&mut self, x: &[f32], retain: bool) -> Result<Vec<f32>> {
        let b = self.batch;
        self.acts.clear();
        self.pool_masks.clear();
        self.bn_mu.clear();
        self.bn_psi.clear();

        let mut cur = x.to_vec();
        let mut wi = 0;
        for li in 0..self.plan.layers.len() {
            let layer = self.plan.layers[li].clone();
            match layer {
                LayerPlan::Dense { k, n, first } => {
                    if retain {
                        self.acts.push(cur.clone()); // retained X_l (f32!)
                    }
                    // binarize input (except first layer) + weights
                    let a = if first { cur.clone() } else { sign_vec(&cur) };
                    let bw = self.signed_w(wi, k, n);
                    let mut y = vec![0.0f32; b * n];
                    self.gemm(b, k, n, &a, &bw, &mut y);
                    let (xn, mu, psi) = bn_l2_forward(&y, b, n, &self.betas[wi].to_f32());
                    if retain {
                        self.bn_mu.push(mu);
                        self.bn_psi.push(psi);
                        self.acts.push(xn.clone()); // x_{l+1} retained
                    }
                    cur = xn;
                    wi += 1;
                }
                LayerPlan::Conv { h, w, cin, cout, kside, first } => {
                    if retain {
                        self.acts.push(cur.clone());
                    }
                    let k = kside * kside * cin;
                    let y = if first || self.accel == Accel::Naive {
                        // real-input (or direct-loop) f32 path
                        let a = if first { cur.clone() } else { sign_vec(&cur) };
                        let bw = self.signed_w(wi, k, cout);
                        self.conv_forward(&a, &bw, b, h, w, cin, cout, kside)
                    } else {
                        // fused binary path: patches signed+packed
                        // straight into row panels (no f32 cols, no
                        // sign_vec copy), XNOR against the cached
                        // packed Ŵᵀ, then the masked SAME-padding
                        // edge correction back to zero-pad semantics
                        let backend = self.accel.backend();
                        let xhat = im2col_packed(&cur, b, h, w, cin, kside, &backend.pool());
                        let weights = &self.weights;
                        let pack = || BitMatrix::pack(k, cout, &weights[wi].to_f32());
                        let wt = self.wcache.wt_via_transpose(wi, pack);
                        let mut y = vec![0.0f32; b * h * w * cout];
                        backend.xnor_gemm(&xhat, wt, &mut y);
                        subtract_pad_contrib(&mut y, wt, b, h, w, cin, kside);
                        y
                    };
                    let (xn, mu, psi) =
                        bn_l2_forward(&y, b * h * w, cout, &self.betas[wi].to_f32());
                    if retain {
                        self.bn_mu.push(mu);
                        self.bn_psi.push(psi);
                        self.acts.push(xn.clone());
                    }
                    cur = xn;
                    wi += 1;
                }
                LayerPlan::MaxPool { h, w, c } => {
                    let (out, mask) = maxpool_forward(&cur, b, h, w, c);
                    if retain {
                        self.pool_masks.push(mask);
                    }
                    cur = out;
                }
                LayerPlan::Flatten => { /* layout already flat NHWC */ }
            }
        }
        Ok(cur)
    }

    #[allow(clippy::too_many_arguments)]
    fn conv_forward(
        &self,
        a: &[f32],
        w: &[f32],
        b: usize,
        h: usize,
        wd: usize,
        cin: usize,
        cout: usize,
        kside: usize,
    ) -> Vec<f32> {
        match self.accel {
            Accel::Naive => conv_direct(a, w, b, h, wd, cin, cout, kside),
            _ => {
                // im2col (transient memory-for-speed buffer) + GEMM
                let k = kside * kside * cin;
                let cols = im2col(a, b, h, wd, cin, kside);
                let mut y = vec![0.0f32; b * h * wd * cout];
                self.gemm(b * h * wd, k, cout, &cols, w, &mut y);
                y
            }
        }
    }

    fn backward(&mut self, dlogits: Vec<f32>, lr: f32) -> Result<()> {
        let b = self.batch;
        let mut dcur = dlogits;
        let mut wi = self.weights.len();
        let mut act_i = self.acts.len();
        let mut pool_i = self.pool_masks.len();

        for st in self.opt_w.iter_mut().chain(self.opt_b.iter_mut()) {
            st.tick();
        }

        for li in (0..self.plan.layers.len()).rev() {
            let layer = self.plan.layers[li].clone();
            match layer {
                LayerPlan::Dense { k, n, first } => {
                    wi -= 1;
                    act_i -= 2;
                    let rows = b;
                    let (dy, dbeta) = bn_l2_backward(
                        &dcur,
                        &self.acts[act_i + 1],
                        &self.betas[wi].to_f32(),
                        &self.bn_psi[wi],
                        rows,
                        n,
                    );
                    // dX = dY @ W^T  (Ŵᵀ from the per-step cache via
                    // the word-level block transpose)
                    let mut dx = {
                        let wt = self.signed_wt(wi, k, n);
                        let mut dx = vec![0.0f32; rows * k];
                        self.gemm(rows, n, k, &dy, &wt, &mut dx);
                        dx
                    };
                    if !first {
                        ste_mask_apply(&mut dx, &self.acts[act_i]);
                    }
                    // dW = X̂ᵀ·dY — transpose-free: the rows×k X̂ᵀ copy
                    // of the pre-fusion path never exists
                    let backend = self.accel.backend();
                    let mut dw = vec![0.0f32; k * n];
                    if first {
                        backend.gemm_f32_at(rows, k, n, &self.acts[act_i], &dy, &mut dw);
                    } else {
                        let xhat = sign_vec(&self.acts[act_i]);
                        backend.gemm_f32_at(rows, k, n, &xhat, &dy, &mut dw);
                    }
                    cancel_wgrad(&mut dw, &self.weights[wi]);
                    self.opt_w[wi].update(&mut self.weights[wi], &dw, lr, true);
                    self.opt_b[wi].update(&mut self.betas[wi], &dbeta, lr, false);
                    self.wcache.invalidate(wi);
                    dcur = dx;
                }
                LayerPlan::Conv { h, w, cin, cout, kside, first } => {
                    wi -= 1;
                    act_i -= 2;
                    let rows = b * h * w;
                    let (dy, dbeta) = bn_l2_backward(
                        &dcur,
                        &self.acts[act_i + 1],
                        &self.betas[wi].to_f32(),
                        &self.bn_psi[wi],
                        rows,
                        cout,
                    );
                    let k = kside * kside * cin;
                    let mut dw = vec![0.0f32; k * cout];
                    let mut dx;
                    if !first && self.accel != Accel::Naive {
                        // fused backward: no rows×k f32 transient.
                        // dX streams per-tap panels of dY·Ŵᵀ straight
                        // into the map (never the full dcols); dW
                        // contracts a re-packed bit-im2col panel (the
                        // forward's fused im2col, +1 pads) against dY,
                        // then subtracts the border dY sums to restore
                        // zero-pad semantics.
                        let backend = self.accel.backend();
                        {
                            let weights = &self.weights;
                            let pack = || BitMatrix::pack(k, cout, &weights[wi].to_f32());
                            let wt = self.wcache.wt_via_transpose(wi, pack);
                            dx = conv_dx_streaming(&dy, wt, b, h, w, cin, kside, backend);
                        }
                        let xh = im2col_packed(
                            &self.acts[act_i],
                            b,
                            h,
                            w,
                            cin,
                            kside,
                            &backend.pool(),
                        );
                        backend.packed_at_gemm_f32(&xh, &dy, cout, &mut dw);
                        drop(xh);
                        subtract_pad_dw_contrib(&mut dw, &dy, b, h, w, cin, cout, kside);
                    } else {
                        // reference path (real-input first layer /
                        // naive accel): f32 im2col math, each rows×k
                        // buffer scoped to die as soon as it is
                        // consumed — peak one such buffer, not three
                        dx = {
                            let wt = self.signed_wt(wi, k, cout);
                            let mut dcols = vec![0.0f32; rows * k];
                            self.gemm(rows, cout, k, &dy, &wt, &mut dcols);
                            col2im(&dcols, b, h, w, cin, kside)
                        };
                        let backend = self.accel.backend();
                        let cols = {
                            let xin = &self.acts[act_i];
                            if first {
                                // real-input layer: im2col the retained
                                // activation in place, no copy
                                im2col(xin, b, h, w, cin, kside)
                            } else {
                                let xhat = sign_vec(xin);
                                im2col(&xhat, b, h, w, cin, kside)
                            }
                        };
                        backend.gemm_f32_at(rows, k, cout, &cols, &dy, &mut dw);
                    }
                    if !first {
                        ste_mask_apply(&mut dx, &self.acts[act_i]);
                    }
                    cancel_wgrad(&mut dw, &self.weights[wi]);
                    self.opt_w[wi].update(&mut self.weights[wi], &dw, lr, true);
                    self.opt_b[wi].update(&mut self.betas[wi], &dbeta, lr, false);
                    self.wcache.invalidate(wi);
                    dcur = dx;
                }
                LayerPlan::MaxPool { h, w, c } => {
                    pool_i -= 1;
                    dcur = maxpool_backward(&dcur, &self.pool_masks[pool_i], b, h, w, c);
                }
                LayerPlan::Flatten => {}
            }
        }
        Ok(())
    }
}

impl StepEngine for StandardTrainer {
    fn train_step(&mut self, x: &[f32], labels: &[usize], lr: f32) -> Result<(f32, f32)> {
        if x.len() != self.batch * self.plan.input_elems || labels.len() != self.batch {
            bail!("bad batch shapes");
        }
        let logits = self.forward(x, true)?;
        let classes = self.plan.classes;
        let mut dlogits = vec![0.0f32; self.batch * classes];
        let (loss, acc) = softmax_xent_grad(&logits, labels, classes, &mut dlogits);
        self.backward(dlogits, lr)?;
        // drop per-step residuals (lifetimes end with the step)
        self.acts.clear();
        self.pool_masks.clear();
        self.bn_mu.clear();
        self.bn_psi.clear();
        Ok((loss, acc))
    }

    fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)> {
        let logits = self.forward(x, false)?;
        let classes = self.plan.classes;
        let mut d = vec![0.0f32; self.batch * classes];
        Ok(softmax_xent_grad(&logits, labels, classes, &mut d))
    }

    fn state_bytes(&self) -> usize {
        self.weights.iter().map(Store::heap_bytes).sum::<usize>()
            + self.betas.iter().map(Store::heap_bytes).sum::<usize>()
            + self.opt_w.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.opt_b.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.wcache.heap_bytes()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn weights_snapshot(&self) -> Vec<Vec<f32>> {
        // interleaved [w0, beta0, ...] — see ProposedTrainer
        let mut out = Vec::with_capacity(self.weights.len() * 2);
        for (w, b) in self.weights.iter().zip(&self.betas) {
            out.push(w.to_f32());
            out.push(b.to_f32());
        }
        out
    }

    fn load_weights(&mut self, w: &[Vec<f32>]) -> Result<()> {
        if w.len() != self.weights.len() * 2 {
            bail!("snapshot layer count mismatch");
        }
        for (i, chunk) in w.chunks(2).enumerate() {
            if chunk[0].len() != self.weights[i].len()
                || chunk[1].len() != self.betas[i].len()
            {
                bail!("snapshot shape mismatch at layer {i}");
            }
            self.weights[i] = Store::F32(chunk[0].clone());
            self.betas[i] = Store::F32(chunk[1].clone());
        }
        self.wcache.invalidate_all();
        Ok(())
    }
}

// ----------------------------------------------------- shared helpers
// (pub(crate): the proposed engine reuses the float kernels)

pub(crate) fn sign_vec(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect()
}

pub fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = a[r * cols + c];
        }
    }
    t
}

/// STE gradient cancellation: dx ⊙ 1{|x| ≤ 1}.
pub(crate) fn ste_mask_apply(dx: &mut [f32], x: &[f32]) {
    for (d, &v) in dx.iter_mut().zip(x) {
        if v.abs() > 1.0 {
            *d = 0.0;
        }
    }
}

/// Weight gradient cancellation (Courbariaux): zero where |w| > 1.
pub(crate) fn cancel_wgrad(dw: &mut [f32], w: &Store) {
    for (i, d) in dw.iter_mut().enumerate() {
        if w.get(i).abs() > 1.0 {
            *d = 0.0;
        }
    }
}

/// ℓ2 batch norm forward over (rows × channels): Alg. 1 lines 5-7.
pub(crate) fn bn_l2_forward(
    y: &[f32],
    rows: usize,
    channels: usize,
    beta: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut mu = vec![0.0f32; channels];
    let mut psi = vec![0.0f32; channels];
    for r in 0..rows {
        for c in 0..channels {
            mu[c] += y[r * channels + c];
        }
    }
    for m in mu.iter_mut() {
        *m /= rows as f32;
    }
    for r in 0..rows {
        for c in 0..channels {
            let d = y[r * channels + c] - mu[c];
            psi[c] += d * d;
        }
    }
    for p in psi.iter_mut() {
        *p = (*p / rows as f32 + 1e-5).sqrt();
    }
    let mut xn = vec![0.0f32; y.len()];
    for r in 0..rows {
        for c in 0..channels {
            xn[r * channels + c] = (y[r * channels + c] - mu[c]) / psi[c] + beta[c];
        }
    }
    (xn, mu, psi)
}

/// ℓ2 batch norm backward: Alg. 1 lines 10-13 (xn is x_{l+1}).
pub(crate) fn bn_l2_backward(
    dx: &[f32],
    x_next: &[f32],
    beta: &[f32],
    psi: &[f32],
    rows: usize,
    channels: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut mean_v = vec![0.0f32; channels];
    let mut mean_vx = vec![0.0f32; channels];
    let mut dbeta = vec![0.0f32; channels];
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            let xn = x_next[r * channels + c] - beta[c];
            mean_v[c] += v;
            mean_vx[c] += v * xn;
            dbeta[c] += dx[r * channels + c];
        }
    }
    for c in 0..channels {
        mean_v[c] /= rows as f32;
        mean_vx[c] /= rows as f32;
    }
    let mut dy = vec![0.0f32; dx.len()];
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            let xn = x_next[r * channels + c] - beta[c];
            dy[r * channels + c] = v - mean_v[c] - mean_vx[c] * xn;
        }
    }
    (dy, dbeta)
}

/// 2×2 max pool (NHWC); mask stores the winning cell index (0..4).
pub(crate) fn maxpool_forward(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> (Vec<f32>, Vec<u32>) {
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * oh * ow * c];
    let mut mask = vec![0u32; b * oh * ow * c];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u32;
                    for (i, (dy, dx)) in
                        [(0, 0), (0, 1), (1, 0), (1, 1)].iter().enumerate()
                    {
                        let v = x[((bi * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch];
                        if v > best {
                            best = v;
                            bidx = i as u32;
                        }
                    }
                    let o = ((bi * oh + oy) * ow + ox) * c + ch;
                    out[o] = best;
                    mask[o] = bidx;
                }
            }
        }
    }
    (out, mask)
}

pub(crate) fn maxpool_backward(
    dout: &[f32],
    mask: &[u32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<f32> {
    let (oh, ow) = (h / 2, w / 2);
    let mut dx = vec![0.0f32; b * h * w * c];
    const OFF: [(usize, usize); 4] = [(0, 0), (0, 1), (1, 0), (1, 1)];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let o = ((bi * oh + oy) * ow + ox) * c + ch;
                    let (dy, dxo) = OFF[mask[o] as usize];
                    dx[((bi * h + oy * 2 + dy) * w + ox * 2 + dxo) * c + ch] = dout[o];
                }
            }
        }
    }
    dx
}

/// im2col for stride-1 SAME kxk conv, NHWC: output (B·H·W, k²·Cin).
/// The f32 reference the fused `bitops::im2col_packed` is bit-exact
/// against (and the pre-fusion baseline the conv bench diffs).
pub fn im2col(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
) -> Vec<f32> {
    assert!(kside % 2 == 1, "SAME conv requires an odd kernel side, got {kside}");
    let k = kside * kside * cin;
    let pad = (kside - 1) / 2;
    let mut cols = vec![0.0f32; b * h * w * k];
    for bi in 0..b {
        for y in 0..h {
            for x0 in 0..w {
                let row = ((bi * h + y) * w + x0) * k;
                let mut idx = row;
                for ky in 0..kside {
                    let sy = y as isize + ky as isize - pad as isize;
                    for kx in 0..kside {
                        let sx = x0 as isize + kx as isize - pad as isize;
                        if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                            let src = ((bi * h + sy as usize) * w + sx as usize) * cin;
                            cols[idx..idx + cin].copy_from_slice(&x[src..src + cin]);
                        }
                        idx += cin;
                    }
                }
            }
        }
    }
    cols
}

/// col2im: scatter-add patch grads back to the input grad (SAME, s=1).
/// The f32 reference the streaming `bitops::conv_dx_streaming` path is
/// equivalent to (and the pre-fusion baseline the backward bench runs).
pub fn col2im(
    dcols: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
) -> Vec<f32> {
    assert!(kside % 2 == 1, "SAME conv requires an odd kernel side, got {kside}");
    let k = kside * kside * cin;
    let pad = (kside - 1) / 2;
    let mut dx = vec![0.0f32; b * h * w * cin];
    for bi in 0..b {
        for y in 0..h {
            for x0 in 0..w {
                let row = ((bi * h + y) * w + x0) * k;
                let mut idx = row;
                for ky in 0..kside {
                    let sy = y as isize + ky as isize - pad as isize;
                    for kx in 0..kside {
                        let sx = x0 as isize + kx as isize - pad as isize;
                        if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                            let dst = ((bi * h + sy as usize) * w + sx as usize) * cin;
                            for ci in 0..cin {
                                dx[dst + ci] += dcols[idx + ci];
                            }
                        }
                        idx += cin;
                    }
                }
            }
        }
    }
    dx
}

/// Direct SAME stride-1 convolution (naïve mode: no im2col buffer).
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_direct(
    x: &[f32],
    wgt: &[f32], // (k², cin, cout) flattened as kside*kside*cin rows × cout
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    kside: usize,
) -> Vec<f32> {
    let pad = (kside - 1) / 2;
    let mut y = vec![0.0f32; b * h * w * cout];
    for bi in 0..b {
        for oy in 0..h {
            for ox in 0..w {
                let orow = ((bi * h + oy) * w + ox) * cout;
                for ky in 0..kside {
                    let sy = oy as isize + ky as isize - pad as isize;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for kx in 0..kside {
                        let sx = ox as isize + kx as isize - pad as isize;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let xrow = ((bi * h + sy as usize) * w + sx as usize) * cin;
                        let wrow = (ky * kside + kx) * cin;
                        for ci in 0..cin {
                            let xv = x[xrow + ci];
                            let wr = (wrow + ci) * cout;
                            for co in 0..cout {
                                y[orow + co] += xv * wgt[wr + co];
                            }
                        }
                    }
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::gemm::gemm_f32;
    use crate::models::{get, lower};

    fn make(model: &str, batch: usize, accel: Accel) -> StandardTrainer {
        let g = lower(&get(model).unwrap()).unwrap();
        StandardTrainer::new(&g, batch, "adam", accel, 42).unwrap()
    }

    fn toy_batch(n: usize, k: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
        let mut g = Pcg32::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes).map(|_| g.normal_vec(k)).collect();
        let mut x = Vec::with_capacity(n * k);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            for j in 0..k {
                x.push(protos[c][j] + 0.3 * g.normal());
            }
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn mlp_mini_learns() {
        let mut t = make("mlp_mini", 32, Accel::Blocked);
        let (x, y) = toy_batch(32, 64, 10, 1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    fn conv_net_learns() {
        let mut t = make("cnv_mini", 16, Accel::Blocked);
        let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.75, "{first:?} -> {last}");
    }

    #[test]
    fn naive_and_blocked_agree() {
        let mut a = make("mlp_mini", 8, Accel::Naive);
        let mut b = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 3);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-4, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tiled_matches_blocked_exactly() {
        // tiled re-bands the same kernels (and both fuse the binary
        // conv path identically), so runs are identical — conv models
        // exercise the bit-im2col + pad-correction pipeline
        for (model, batch, k) in [("mlp_mini", 8, 64), ("cnv_mini", 4, 16 * 16 * 3)] {
            let mut a = make(model, batch, Accel::Blocked);
            let mut b = make(model, batch, Accel::Tiled(2));
            let (x, y) = toy_batch(batch, k, 10, 3);
            for step in 0..3 {
                let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
                let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
                assert!((la - lb).abs() < 1e-6, "{model} step {step}: {la} vs {lb}");
            }
            for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
                assert_eq!(wa, wb, "{model}");
            }
        }
    }

    #[test]
    fn fused_conv_matches_naive_direct() {
        // the fused XNOR conv (+1-packed pads + masked edge
        // correction) against conv_direct's true zero padding: same
        // zero-pad semantics, so whole conv training runs agree
        let mut a = make("cnv_mini", 4, Accel::Naive);
        let mut b = make("cnv_mini", 4, Accel::Blocked);
        let (x, y) = toy_batch(4, 16 * 16 * 3, 10, 6);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-3, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn weights_packed_at_most_once_per_step() {
        let mut t = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 9);
        t.train_step(&x, &y, 0.01).unwrap();
        let per_step = t.weight_pack_count();
        // one pack per weight layer per step: forward packs Ŵ, the
        // backward Ŵᵀ is a transpose of the cache, not a new pack
        assert!(per_step >= 1 && per_step <= t.weights.len(), "{per_step}");
        t.train_step(&x, &y, 0.01).unwrap();
        assert_eq!(t.weight_pack_count(), 2 * per_step);
    }

    #[test]
    fn conv_direct_matches_im2col_gemm() {
        let mut g = Pcg32::new(4);
        let (b, h, w, cin, cout, kside) = (2, 5, 5, 3, 4, 3);
        let x = g.normal_vec(b * h * w * cin);
        let wg = g.normal_vec(kside * kside * cin * cout);
        let direct = conv_direct(&x, &wg, b, h, w, cin, cout, kside);
        let cols = im2col(&x, b, h, w, cin, kside);
        let mut gemm_out = vec![0.0f32; b * h * w * cout];
        gemm_f32(b * h * w, kside * kside * cin, cout, &cols, &wg, &mut gemm_out);
        for i in 0..direct.len() {
            assert!((direct[i] - gemm_out[i]).abs() < 1e-4, "{i}");
        }
    }

    #[test]
    fn col2im_adjoint_of_im2col() {
        // <im2col(x), c> == <x, col2im(c)> (adjointness)
        let mut g = Pcg32::new(5);
        let (b, h, w, cin, kside) = (1, 4, 4, 2, 3);
        let x = g.normal_vec(b * h * w * cin);
        let cvec = g.normal_vec(b * h * w * kside * kside * cin);
        let cx = im2col(&x, b, h, w, cin, kside);
        let ic: f32 = cx.iter().zip(&cvec).map(|(a, b)| a * b).sum();
        let xc = col2im(&cvec, b, h, w, cin, kside);
        let ci: f32 = x.iter().zip(&xc).map(|(a, b)| a * b).sum();
        assert!((ic - ci).abs() < 1e-3, "{ic} vs {ci}");
    }

    #[test]
    fn maxpool_roundtrip() {
        let x = vec![
            1.0, 5.0, 2.0, 0.0, //
            3.0, 4.0, 8.0, 1.0, //
            0.0, 2.0, 1.0, 1.0, //
            9.0, 1.0, 0.0, 3.0,
        ];
        let (out, mask) = maxpool_forward(&x, 1, 4, 4, 1);
        assert_eq!(out, vec![5.0, 8.0, 9.0, 3.0]);
        let dx = maxpool_backward(&[1.0, 2.0, 3.0, 4.0], &mask, 1, 4, 4, 1);
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), 4);
        assert_eq!(dx[1], 1.0); // the 5.0 cell
        assert_eq!(dx[12], 3.0); // the 9.0 cell
    }

    #[test]
    fn bn_l2_normalizes() {
        let mut g = Pcg32::new(6);
        let rows = 64;
        let ch = 4;
        let y: Vec<f32> = g.normal_vec(rows * ch).iter().map(|v| v * 3.0 + 1.0).collect();
        let (xn, _, _) = bn_l2_forward(&y, rows, ch, &vec![0.0; ch]);
        for c in 0..ch {
            let m: f32 = (0..rows).map(|r| xn[r * ch + c]).sum::<f32>() / rows as f32;
            let v: f32 =
                (0..rows).map(|r| (xn[r * ch + c] - m).powi(2)).sum::<f32>() / rows as f32;
            assert!(m.abs() < 1e-4);
            assert!((v - 1.0).abs() < 0.05, "{v}");
        }
    }

    #[test]
    fn eval_does_not_mutate() {
        let mut t = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 7);
        let before = t.weights_snapshot();
        t.eval(&x, &y).unwrap();
        assert_eq!(before, t.weights_snapshot());
    }

    #[test]
    fn snapshot_roundtrip() {
        let mut a = make("mlp_mini", 8, Accel::Blocked);
        let mut b = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 8);
        a.train_step(&x, &y, 0.01).unwrap();
        b.load_weights(&a.weights_snapshot()).unwrap();
        let (la, _) = a.eval(&x, &y).unwrap();
        let (lb, _) = b.eval(&x, &y).unwrap();
        assert!((la - lb).abs() < 1e-6);
    }
}
