//! Algorithm 1 — Courbariaux & Bengio's standard BNN training step,
//! float32 everywhere, ℓ2 batch normalization.
//!
//! Memory behaviour is the point: every layer's input activations are
//! retained in f32 between forward and backward (Fig. 1's red
//! dependency), pool masks are f32-indexed, weights/momenta/grads are
//! f32 — exactly the left half of Table 2, so the tracking allocator
//! measures what the paper's standard prototype measured.
//!
//! The layer-graph control flow (pooling, global pooling, residual
//! skips) lives in [`super::ops`]; this file implements the standard
//! engine's per-matmul-layer forward/backward over any [`ConvGeom`].
//! Binary×binary matmuls — conv *and* hidden dense layers — run the
//! packed XNOR path on the accelerated tiers (dense needs no pad
//! correction: there is no padding, so the XNOR product is already
//! the exact ±1 dot product).

use anyhow::{bail, Result};

use super::ops::{self, EngineOps};
use super::plan::{LayerPlan, Plan};
use super::{glorot_init, softmax_xent_grad, Accel, StepEngine};
use crate::bitops::{
    conv_dx_streaming, im2col_packed, subtract_pad_contrib, subtract_pad_dw_contrib, BitMatrix,
    ConvGeom, PackedWeightCache,
};
use crate::models::Graph;
use crate::optim::{OptState, Store};
use crate::util::rng::Pcg32;

pub struct StandardTrainer {
    plan: Plan,
    batch: usize,
    accel: Accel,
    // parameters (f32 latent weights, clipped to [-1,1]) + BN biases
    weights: Vec<Store>,
    betas: Vec<Store>,
    opt_w: Vec<OptState>,
    opt_b: Vec<OptState>,
    // retained per step (transient between fwd and bwd).  Each matmul
    // layer wi pushes exactly two f32 activations in order: its input
    // at index 2·wi and its BN output at 2·wi + 1.
    acts: Vec<Vec<f32>>,
    pool_masks: Vec<Vec<u32>>, // argmax index per pooled cell (f32-class storage)
    bn_mu: Vec<Vec<f32>>,
    bn_psi: Vec<Vec<f32>>,
    /// Per-step binarized-weight cache: sign(W) is packed once per
    /// step and unpacked per use; invalidated on weight update.
    wcache: PackedWeightCache,
}

impl StandardTrainer {
    pub fn new(
        graph: &Graph,
        batch: usize,
        optimizer: &str,
        accel: Accel,
        seed: u64,
    ) -> Result<StandardTrainer> {
        let plan = Plan::from_graph(graph)?;
        if batch == 0 {
            bail!("batch must be positive");
        }
        let mut rng = Pcg32::new(seed);
        let mut weights = Vec::new();
        let mut betas = Vec::new();
        let mut opt_w = Vec::new();
        let mut opt_b = Vec::new();
        for l in &plan.layers {
            let wl = l.weight_len();
            if wl == 0 {
                continue;
            }
            let w = glorot_init(&mut rng, l.fan_in(), l.channels(), wl);
            weights.push(Store::F32(w));
            betas.push(Store::F32(vec![0.0; l.channels()]));
            opt_w.push(OptState::new(optimizer, wl, false));
            opt_b.push(OptState::new(optimizer, l.channels(), false));
        }
        let wcache = PackedWeightCache::new(weights.len());
        Ok(StandardTrainer {
            plan,
            batch,
            accel,
            weights,
            betas,
            opt_w,
            opt_b,
            acts: Vec::new(),
            pool_masks: Vec::new(),
            bn_mu: Vec::new(),
            bn_psi: Vec::new(),
            wcache,
        })
    }

    /// Total weight packs so far (the once-per-step probe).
    pub fn weight_pack_count(&self) -> usize {
        self.wcache.pack_count()
    }

    /// GEMM dispatch honoring the accel mode.
    fn gemm(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        self.accel.backend().gemm_f32(m, k, n, a, b, out);
    }

    /// Binarized weights Ŵ (k×n, ±1 f32) via the per-step cache —
    /// packed once per step instead of sign_vec'd per matmul.
    fn signed_w(&mut self, wi: usize, k: usize, n: usize) -> Vec<f32> {
        let weights = &self.weights;
        self.wcache
            .w(wi, || BitMatrix::pack(k, n, &weights[wi].to_f32()))
            .unpack()
    }

    /// Binarized transposed weights Ŵᵀ (n×k, ±1 f32): derived from
    /// the cached Ŵ by the word-level block transpose.
    fn signed_wt(&mut self, wi: usize, k: usize, n: usize) -> Vec<f32> {
        let weights = &self.weights;
        self.wcache
            .wt_via_transpose(wi, || BitMatrix::pack(k, n, &weights[wi].to_f32()))
            .unpack()
    }

    fn forward(&mut self, x: &[f32], retain: bool) -> Result<Vec<f32>> {
        self.acts.clear();
        self.pool_masks.clear();
        self.bn_mu.clear();
        self.bn_psi.clear();
        let layers = self.plan.layers.clone();
        ops::forward_plan(self, &layers, x, retain)
    }

    fn backward(&mut self, dlogits: Vec<f32>, lr: f32) -> Result<()> {
        for st in self.opt_w.iter_mut().chain(self.opt_b.iter_mut()) {
            st.tick();
        }
        let layers = self.plan.layers.clone();
        ops::backward_plan(self, &layers, dlogits, lr)
    }

    /// Real-input (or direct-loop) f32 conv forward.
    fn conv_forward(&self, a: &[f32], w: &[f32], b: usize, g: ConvGeom, cout: usize) -> Vec<f32> {
        match self.accel {
            Accel::Naive => conv_direct(a, w, b, g, cout),
            _ => {
                // im2col (transient memory-for-speed buffer) + GEMM
                let cols = im2col(a, b, g);
                let mut y = vec![0.0f32; g.rows(b) * cout];
                self.gemm(g.rows(b), g.k(), cout, &cols, w, &mut y);
                y
            }
        }
    }
}

impl EngineOps for StandardTrainer {
    type Grad = Vec<f32>;

    fn batch(&self) -> usize {
        self.batch
    }

    fn grad_to_f32(g: Vec<f32>) -> Vec<f32> {
        g
    }

    fn grad_from_f32(v: Vec<f32>) -> Vec<f32> {
        v
    }

    fn matmul_forward(
        &mut self,
        cur: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        retain: bool,
    ) -> Result<Vec<f32>> {
        let b = self.batch;
        let (y, rows, n) = match *layer {
            LayerPlan::Dense { k, n, first } => {
                if retain {
                    self.acts.push(cur.clone()); // retained X_l (f32!)
                }
                let y = if first || self.accel == Accel::Naive {
                    // f32 GEMM over the binarized operands
                    let a = if first { cur } else { sign_vec(&cur) };
                    let bw = self.signed_w(wi, k, n);
                    let mut y = vec![0.0f32; b * n];
                    self.gemm(b, k, n, &a, &bw, &mut y);
                    y
                } else {
                    // binary×binary hidden fc: pack X̂ and run the
                    // XNOR-popcount path against the cached packed Ŵᵀ
                    // — no padding, so no sign correction is needed
                    // and the result is the exact ±1 dot product
                    let xhat = BitMatrix::pack(b, k, &cur);
                    let weights = &self.weights;
                    let pack = || BitMatrix::pack(k, n, &weights[wi].to_f32());
                    let wt = self.wcache.wt_via_transpose(wi, pack);
                    let mut y = vec![0.0f32; b * n];
                    self.accel.backend().xnor_gemm(&xhat, wt, &mut y);
                    y
                };
                (y, b, n)
            }
            LayerPlan::Conv { g, cout, first } => {
                if retain {
                    self.acts.push(cur.clone());
                }
                let rows = g.rows(b);
                let y = if first || self.accel == Accel::Naive {
                    // real-input (or direct-loop) f32 path
                    let a = if first { cur } else { sign_vec(&cur) };
                    let bw = self.signed_w(wi, g.k(), cout);
                    self.conv_forward(&a, &bw, b, g, cout)
                } else {
                    // fused binary path: patches signed+packed
                    // straight into row panels (no f32 cols, no
                    // sign_vec copy), XNOR against the cached packed
                    // Ŵᵀ, then the masked padding edge correction
                    // back to zero-pad semantics (no-op for VALID)
                    let backend = self.accel.backend();
                    let xhat = im2col_packed(&cur, b, g, &backend.pool());
                    let weights = &self.weights;
                    let pack = || BitMatrix::pack(g.k(), cout, &weights[wi].to_f32());
                    let wt = self.wcache.wt_via_transpose(wi, pack);
                    let mut y = vec![0.0f32; rows * cout];
                    backend.xnor_gemm(&xhat, wt, &mut y);
                    subtract_pad_contrib(&mut y, wt, b, g);
                    y
                };
                (y, rows, cout)
            }
            _ => unreachable!("matmul_forward on a non-matmul layer"),
        };
        let (xn, mu, psi) = bn_l2_forward(&y, rows, n, &self.betas[wi].to_f32());
        if retain {
            self.bn_mu.push(mu);
            self.bn_psi.push(psi);
            self.acts.push(xn.clone()); // x_{l+1} retained
        }
        Ok(xn)
    }

    fn matmul_backward(
        &mut self,
        dnext: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        lr: f32,
    ) -> Result<Vec<f32>> {
        let b = self.batch;
        match *layer {
            LayerPlan::Dense { k, n, first } => {
                let rows = b;
                let (dy, dbeta) = bn_l2_backward(
                    &dnext,
                    &self.acts[2 * wi + 1],
                    &self.betas[wi].to_f32(),
                    &self.bn_psi[wi],
                    rows,
                    n,
                );
                // dX = dY @ W^T  (Ŵᵀ from the per-step cache via the
                // word-level block transpose)
                let mut dx = {
                    let wt = self.signed_wt(wi, k, n);
                    let mut dx = vec![0.0f32; rows * k];
                    self.gemm(rows, n, k, &dy, &wt, &mut dx);
                    dx
                };
                if !first {
                    ste_mask_apply(&mut dx, &self.acts[2 * wi]);
                }
                // dW = X̂ᵀ·dY — transpose-free.  On the accelerated
                // tiers the binary X̂ is packed and contracted straight
                // off the bit panel (rows×k f32 sign copy gone);
                // bands split k, never the reduction, so the result is
                // bit-identical across tiers and thread counts.
                let backend = self.accel.backend();
                let mut dw = vec![0.0f32; k * n];
                if first {
                    backend.gemm_f32_at(rows, k, n, &self.acts[2 * wi], &dy, &mut dw);
                } else if self.accel == Accel::Naive {
                    let xhat = sign_vec(&self.acts[2 * wi]);
                    backend.gemm_f32_at(rows, k, n, &xhat, &dy, &mut dw);
                } else {
                    let xhat = BitMatrix::pack(rows, k, &self.acts[2 * wi]);
                    backend.packed_at_gemm_f32(&xhat, &dy, n, &mut dw);
                }
                cancel_wgrad(&mut dw, &self.weights[wi]);
                self.opt_w[wi].update(&mut self.weights[wi], &dw, lr, true);
                self.opt_b[wi].update(&mut self.betas[wi], &dbeta, lr, false);
                self.wcache.invalidate(wi);
                Ok(dx)
            }
            LayerPlan::Conv { g, cout, first } => {
                let rows = g.rows(b);
                let (dy, dbeta) = bn_l2_backward(
                    &dnext,
                    &self.acts[2 * wi + 1],
                    &self.betas[wi].to_f32(),
                    &self.bn_psi[wi],
                    rows,
                    cout,
                );
                let k = g.k();
                let mut dw = vec![0.0f32; k * cout];
                let mut dx;
                if !first && self.accel != Accel::Naive {
                    // fused backward: no rows×k f32 transient.
                    // dX streams per-tap panels of dY·Ŵᵀ straight
                    // into the map (never the full dcols); dW
                    // contracts a re-packed bit-im2col panel (the
                    // forward's fused im2col, +1 pads) against dY,
                    // then subtracts the border dY sums to restore
                    // zero-pad semantics (both no-ops for VALID).
                    let backend = self.accel.backend();
                    {
                        let weights = &self.weights;
                        let pack = || BitMatrix::pack(k, cout, &weights[wi].to_f32());
                        let wt = self.wcache.wt_via_transpose(wi, pack);
                        dx = conv_dx_streaming(&dy, wt, b, g, backend);
                    }
                    let xh = im2col_packed(&self.acts[2 * wi], b, g, &backend.pool());
                    backend.packed_at_gemm_f32(&xh, &dy, cout, &mut dw);
                    drop(xh);
                    subtract_pad_dw_contrib(&mut dw, &dy, b, g, cout);
                } else {
                    // reference path (real-input first layer / naive
                    // accel): f32 im2col math, each rows×k buffer
                    // scoped to die as soon as it is consumed — peak
                    // one such buffer, not three
                    dx = {
                        let wt = self.signed_wt(wi, k, cout);
                        let mut dcols = vec![0.0f32; rows * k];
                        self.gemm(rows, cout, k, &dy, &wt, &mut dcols);
                        col2im(&dcols, b, g)
                    };
                    let backend = self.accel.backend();
                    let cols = {
                        let xin = &self.acts[2 * wi];
                        if first {
                            // real-input layer: im2col the retained
                            // activation in place, no copy
                            im2col(xin, b, g)
                        } else {
                            let xhat = sign_vec(xin);
                            im2col(&xhat, b, g)
                        }
                    };
                    backend.gemm_f32_at(rows, k, cout, &cols, &dy, &mut dw);
                }
                if !first {
                    ste_mask_apply(&mut dx, &self.acts[2 * wi]);
                }
                cancel_wgrad(&mut dw, &self.weights[wi]);
                self.opt_w[wi].update(&mut self.weights[wi], &dw, lr, true);
                self.opt_b[wi].update(&mut self.betas[wi], &dbeta, lr, false);
                self.wcache.invalidate(wi);
                Ok(dx)
            }
            _ => unreachable!("matmul_backward on a non-matmul layer"),
        }
    }

    fn pool_forward(
        &mut self,
        cur: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        retain: bool,
    ) -> Vec<f32> {
        let (out, mask) = maxpool_forward(&cur, self.batch, h, w, c);
        if retain {
            self.pool_masks.push(mask);
        }
        out
    }

    fn pool_backward(&mut self, dnext: Vec<f32>, h: usize, w: usize, c: usize) -> Vec<f32> {
        let mask = self.pool_masks.pop().expect("pool mask stack underflow");
        maxpool_backward(&dnext, &mask, self.batch, h, w, c)
    }
}

impl StepEngine for StandardTrainer {
    fn train_step(&mut self, x: &[f32], labels: &[usize], lr: f32) -> Result<(f32, f32)> {
        if x.len() != self.batch * self.plan.input_elems || labels.len() != self.batch {
            bail!("bad batch shapes");
        }
        let logits = self.forward(x, true)?;
        let classes = self.plan.classes;
        let mut dlogits = vec![0.0f32; self.batch * classes];
        let (loss, acc) = softmax_xent_grad(&logits, labels, classes, &mut dlogits);
        self.backward(dlogits, lr)?;
        // drop per-step residuals (lifetimes end with the step)
        self.acts.clear();
        self.pool_masks.clear();
        self.bn_mu.clear();
        self.bn_psi.clear();
        Ok((loss, acc))
    }

    fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)> {
        let logits = self.forward(x, false)?;
        let classes = self.plan.classes;
        let mut d = vec![0.0f32; self.batch * classes];
        Ok(softmax_xent_grad(&logits, labels, classes, &mut d))
    }

    fn state_bytes(&self) -> usize {
        self.weights.iter().map(Store::heap_bytes).sum::<usize>()
            + self.betas.iter().map(Store::heap_bytes).sum::<usize>()
            + self.opt_w.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.opt_b.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.wcache.heap_bytes()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn weights_snapshot(&self) -> Vec<Vec<f32>> {
        // interleaved [w0, beta0, ...] — see ProposedTrainer
        let mut out = Vec::with_capacity(self.weights.len() * 2);
        for (w, b) in self.weights.iter().zip(&self.betas) {
            out.push(w.to_f32());
            out.push(b.to_f32());
        }
        out
    }

    fn load_weights(&mut self, w: &[Vec<f32>]) -> Result<()> {
        if w.len() != self.weights.len() * 2 {
            bail!("snapshot layer count mismatch");
        }
        for (i, chunk) in w.chunks(2).enumerate() {
            if chunk[0].len() != self.weights[i].len()
                || chunk[1].len() != self.betas[i].len()
            {
                bail!("snapshot shape mismatch at layer {i}");
            }
            self.weights[i] = Store::F32(chunk[0].clone());
            self.betas[i] = Store::F32(chunk[1].clone());
        }
        self.wcache.invalidate_all();
        Ok(())
    }
}

// ----------------------------------------------------- shared helpers
// (pub(crate): the proposed engine reuses the float kernels)

pub(crate) fn sign_vec(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect()
}

pub fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut t = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            t[c * rows + r] = a[r * cols + c];
        }
    }
    t
}

/// STE gradient cancellation: dx ⊙ 1{|x| ≤ 1}.
pub(crate) fn ste_mask_apply(dx: &mut [f32], x: &[f32]) {
    for (d, &v) in dx.iter_mut().zip(x) {
        if v.abs() > 1.0 {
            *d = 0.0;
        }
    }
}

/// Weight gradient cancellation (Courbariaux): zero where |w| > 1.
pub(crate) fn cancel_wgrad(dw: &mut [f32], w: &Store) {
    for (i, d) in dw.iter_mut().enumerate() {
        if w.get(i).abs() > 1.0 {
            *d = 0.0;
        }
    }
}

/// ℓ2 batch norm forward over (rows × channels): Alg. 1 lines 5-7.
pub(crate) fn bn_l2_forward(
    y: &[f32],
    rows: usize,
    channels: usize,
    beta: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut mu = vec![0.0f32; channels];
    let mut psi = vec![0.0f32; channels];
    for r in 0..rows {
        for c in 0..channels {
            mu[c] += y[r * channels + c];
        }
    }
    for m in mu.iter_mut() {
        *m /= rows as f32;
    }
    for r in 0..rows {
        for c in 0..channels {
            let d = y[r * channels + c] - mu[c];
            psi[c] += d * d;
        }
    }
    for p in psi.iter_mut() {
        *p = (*p / rows as f32 + 1e-5).sqrt();
    }
    let mut xn = vec![0.0f32; y.len()];
    for r in 0..rows {
        for c in 0..channels {
            xn[r * channels + c] = (y[r * channels + c] - mu[c]) / psi[c] + beta[c];
        }
    }
    (xn, mu, psi)
}

/// ℓ2 batch norm backward: Alg. 1 lines 10-13 (xn is x_{l+1}).
pub(crate) fn bn_l2_backward(
    dx: &[f32],
    x_next: &[f32],
    beta: &[f32],
    psi: &[f32],
    rows: usize,
    channels: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut mean_v = vec![0.0f32; channels];
    let mut mean_vx = vec![0.0f32; channels];
    let mut dbeta = vec![0.0f32; channels];
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            let xn = x_next[r * channels + c] - beta[c];
            mean_v[c] += v;
            mean_vx[c] += v * xn;
            dbeta[c] += dx[r * channels + c];
        }
    }
    for c in 0..channels {
        mean_v[c] /= rows as f32;
        mean_vx[c] /= rows as f32;
    }
    let mut dy = vec![0.0f32; dx.len()];
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            let xn = x_next[r * channels + c] - beta[c];
            dy[r * channels + c] = v - mean_v[c] - mean_vx[c] * xn;
        }
    }
    (dy, dbeta)
}

/// 2×2 max pool (NHWC); mask stores the winning cell index (0..4).
pub(crate) fn maxpool_forward(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> (Vec<f32>, Vec<u32>) {
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * oh * ow * c];
    let mut mask = vec![0u32; b * oh * ow * c];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u32;
                    for (i, (dy, dx)) in
                        [(0, 0), (0, 1), (1, 0), (1, 1)].iter().enumerate()
                    {
                        let v = x[((bi * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch];
                        if v > best {
                            best = v;
                            bidx = i as u32;
                        }
                    }
                    let o = ((bi * oh + oy) * ow + ox) * c + ch;
                    out[o] = best;
                    mask[o] = bidx;
                }
            }
        }
    }
    (out, mask)
}

pub(crate) fn maxpool_backward(
    dout: &[f32],
    mask: &[u32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Vec<f32> {
    let (oh, ow) = (h / 2, w / 2);
    let mut dx = vec![0.0f32; b * h * w * c];
    const OFF: [(usize, usize); 4] = [(0, 0), (0, 1), (1, 0), (1, 1)];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let o = ((bi * oh + oy) * ow + ox) * c + ch;
                    let (dy, dxo) = OFF[mask[o] as usize];
                    dx[((bi * h + oy * 2 + dy) * w + ox * 2 + dxo) * c + ch] = dout[o];
                }
            }
        }
    }
    dx
}

/// im2col for any conv geometry, NHWC: output (B·OH·OW, k²·Cin).
/// The f32 reference the fused `bitops::im2col_packed` is bit-exact
/// against (and the pre-fusion baseline the conv bench diffs).
pub fn im2col(x: &[f32], b: usize, g: ConvGeom) -> Vec<f32> {
    assert_eq!(x.len(), g.in_len(b), "NHWC shape mismatch");
    let k = g.k();
    let mut cols = vec![0.0f32; g.rows(b) * k];
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let mut idx = ((bi * g.oh + oy) * g.ow + ox) * k;
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if sy >= 0 && sy < g.h as isize && sx >= 0 && sx < g.w as isize {
                            let src = ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                            cols[idx..idx + g.cin].copy_from_slice(&x[src..src + g.cin]);
                        }
                        idx += g.cin;
                    }
                }
            }
        }
    }
    cols
}

/// col2im: scatter-add patch grads back to the input grad (any
/// geometry).  The f32 reference the streaming
/// `bitops::conv_dx_streaming` path is equivalent to (and the
/// pre-fusion baseline the backward bench runs).
pub fn col2im(dcols: &[f32], b: usize, g: ConvGeom) -> Vec<f32> {
    let k = g.k();
    assert_eq!(dcols.len(), g.rows(b) * k, "cols shape mismatch");
    let mut dx = vec![0.0f32; g.in_len(b)];
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let mut idx = ((bi * g.oh + oy) * g.ow + ox) * k;
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if sy >= 0 && sy < g.h as isize && sx >= 0 && sx < g.w as isize {
                            let dst = ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                            for ci in 0..g.cin {
                                dx[dst + ci] += dcols[idx + ci];
                            }
                        }
                        idx += g.cin;
                    }
                }
            }
        }
    }
    dx
}

/// Direct convolution for any geometry (naïve mode: no im2col buffer).
pub(crate) fn conv_direct(
    x: &[f32],
    wgt: &[f32], // (k², cin, cout) flattened as kside*kside*cin rows × cout
    b: usize,
    g: ConvGeom,
    cout: usize,
) -> Vec<f32> {
    let mut y = vec![0.0f32; g.rows(b) * cout];
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                let orow = ((bi * g.oh + oy) * g.ow + ox) * cout;
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    if sy < 0 || sy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if sx < 0 || sx >= g.w as isize {
                            continue;
                        }
                        let xrow = ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                        let wrow = (ky * g.kside + kx) * g.cin;
                        for ci in 0..g.cin {
                            let xv = x[xrow + ci];
                            let wr = (wrow + ci) * cout;
                            for co in 0..cout {
                                y[orow + co] += xv * wgt[wr + co];
                            }
                        }
                    }
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::gemm::gemm_f32;
    use crate::models::{get, lower, LayerSpec, ModelSpec};

    fn make(model: &str, batch: usize, accel: Accel) -> StandardTrainer {
        let g = lower(&get(model).unwrap()).unwrap();
        StandardTrainer::new(&g, batch, "adam", accel, 42).unwrap()
    }

    fn toy_batch(n: usize, k: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
        let mut g = Pcg32::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes).map(|_| g.normal_vec(k)).collect();
        let mut x = Vec::with_capacity(n * k);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            for j in 0..k {
                x.push(protos[c][j] + 0.3 * g.normal());
            }
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn mlp_mini_learns() {
        let mut t = make("mlp_mini", 32, Accel::Blocked);
        let (x, y) = toy_batch(32, 64, 10, 1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");
    }

    #[test]
    fn conv_net_learns() {
        let mut t = make("cnv_mini", 16, Accel::Blocked);
        let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.75, "{first:?} -> {last}");
    }

    #[test]
    fn residual_net_learns() {
        // resnete_mini: stem conv + 4 skip blocks (one channel-doubling)
        let mut t = make("resnete_mini", 16, Accel::Blocked);
        let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 12);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last.is_finite());
        assert!(last < first.unwrap(), "{first:?} -> {last}");
    }

    #[test]
    fn naive_and_blocked_agree() {
        let mut a = make("mlp_mini", 8, Accel::Naive);
        let mut b = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 3);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-4, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tiled_matches_blocked_exactly() {
        // tiled re-bands the same kernels (and both fuse the binary
        // conv path identically), so runs are identical — conv and
        // residual models exercise the bit-im2col + pad-correction +
        // skip pipeline
        for (model, batch, k) in [
            ("mlp_mini", 8, 64),
            ("cnv_mini", 4, 16 * 16 * 3),
            ("bireal_mini", 4, 16 * 16 * 3),
        ] {
            let mut a = make(model, batch, Accel::Blocked);
            let mut b = make(model, batch, Accel::Tiled(2));
            let (x, y) = toy_batch(batch, k, 10, 3);
            for step in 0..3 {
                let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
                let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
                assert!((la - lb).abs() < 1e-6, "{model} step {step}: {la} vs {lb}");
            }
            for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
                assert_eq!(wa, wb, "{model}");
            }
        }
    }

    #[test]
    fn fused_conv_matches_naive_direct() {
        // the fused XNOR conv (+1-packed pads + masked edge
        // correction) against conv_direct's true zero padding: same
        // zero-pad semantics, so whole conv training runs agree
        let mut a = make("cnv_mini", 4, Accel::Naive);
        let mut b = make("cnv_mini", 4, Accel::Blocked);
        let (x, y) = toy_batch(4, 16 * 16 * 3, 10, 6);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-3, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn strided_and_valid_convs_train() {
        // strided SAME + VALID convs end to end on the accelerated
        // tiers, agreeing with the naive direct-conv reference
        let spec = ModelSpec {
            name: "strided_valid".into(),
            input_shape: vec![12, 12, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv_s(6, 3, 2).as_first(), // 12 -> 6 SAME s2
                LayerSpec::conv(8, 3).valid(),         // 6 -> 4 VALID
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let graph = lower(&spec).unwrap();
        let (x, y) = toy_batch(4, 12 * 12 * 3, 10, 9);
        let mut a = StandardTrainer::new(&graph, 4, "sgd", Accel::Naive, 5).unwrap();
        let mut b = StandardTrainer::new(&graph, 4, "sgd", Accel::Tiled(2), 5).unwrap();
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-3, "step {step}: {la} vs {lb}");
        }
        for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
            for (u, v) in wa.iter().zip(wb) {
                assert!((u - v).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn weights_packed_at_most_once_per_step() {
        let mut t = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 9);
        t.train_step(&x, &y, 0.01).unwrap();
        let per_step = t.weight_pack_count();
        // one pack per weight layer per step: forward packs Ŵ, the
        // backward Ŵᵀ is a transpose of the cache, not a new pack
        assert!(per_step >= 1 && per_step <= t.weights.len(), "{per_step}");
        t.train_step(&x, &y, 0.01).unwrap();
        assert_eq!(t.weight_pack_count(), 2 * per_step);
    }

    #[test]
    fn conv_direct_matches_im2col_gemm() {
        let mut rng = Pcg32::new(4);
        for g in [
            ConvGeom::same1(5, 5, 3, 3),
            ConvGeom::same(8, 8, 3, 3, 2),
            ConvGeom::valid(7, 7, 2, 3, 1),
            ConvGeom::valid(9, 9, 2, 3, 2),
        ] {
            let b = 2;
            let cout = 4;
            let x = rng.normal_vec(g.in_len(b));
            let wg = rng.normal_vec(g.k() * cout);
            let direct = conv_direct(&x, &wg, b, g, cout);
            let cols = im2col(&x, b, g);
            let mut gemm_out = vec![0.0f32; g.rows(b) * cout];
            gemm_f32(g.rows(b), g.k(), cout, &cols, &wg, &mut gemm_out);
            for i in 0..direct.len() {
                assert!((direct[i] - gemm_out[i]).abs() < 1e-4, "{g:?} @ {i}");
            }
        }
    }

    #[test]
    fn col2im_adjoint_of_im2col() {
        // <im2col(x), c> == <x, col2im(c)> (adjointness), any geometry
        let mut rng = Pcg32::new(5);
        for g in [
            ConvGeom::same1(4, 4, 2, 3),
            ConvGeom::same(7, 7, 2, 3, 2),
            ConvGeom::valid(6, 6, 3, 3, 1),
        ] {
            let b = 1;
            let x = rng.normal_vec(g.in_len(b));
            let cvec = rng.normal_vec(g.rows(b) * g.k());
            let cx = im2col(&x, b, g);
            let ic: f32 = cx.iter().zip(&cvec).map(|(a, b)| a * b).sum();
            let xc = col2im(&cvec, b, g);
            let ci: f32 = x.iter().zip(&xc).map(|(a, b)| a * b).sum();
            assert!((ic - ci).abs() < 1e-3, "{g:?}: {ic} vs {ci}");
        }
    }

    #[test]
    fn maxpool_roundtrip() {
        let x = vec![
            1.0, 5.0, 2.0, 0.0, //
            3.0, 4.0, 8.0, 1.0, //
            0.0, 2.0, 1.0, 1.0, //
            9.0, 1.0, 0.0, 3.0,
        ];
        let (out, mask) = maxpool_forward(&x, 1, 4, 4, 1);
        assert_eq!(out, vec![5.0, 8.0, 9.0, 3.0]);
        let dx = maxpool_backward(&[1.0, 2.0, 3.0, 4.0], &mask, 1, 4, 4, 1);
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), 4);
        assert_eq!(dx[1], 1.0); // the 5.0 cell
        assert_eq!(dx[12], 3.0); // the 9.0 cell
    }

    #[test]
    fn bn_l2_normalizes() {
        let mut g = Pcg32::new(6);
        let rows = 64;
        let ch = 4;
        let y: Vec<f32> = g.normal_vec(rows * ch).iter().map(|v| v * 3.0 + 1.0).collect();
        let (xn, _, _) = bn_l2_forward(&y, rows, ch, &vec![0.0; ch]);
        for c in 0..ch {
            let m: f32 = (0..rows).map(|r| xn[r * ch + c]).sum::<f32>() / rows as f32;
            let v: f32 =
                (0..rows).map(|r| (xn[r * ch + c] - m).powi(2)).sum::<f32>() / rows as f32;
            assert!(m.abs() < 1e-4);
            assert!((v - 1.0).abs() < 0.05, "{v}");
        }
    }

    #[test]
    fn eval_does_not_mutate() {
        let mut t = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 7);
        let before = t.weights_snapshot();
        t.eval(&x, &y).unwrap();
        assert_eq!(before, t.weights_snapshot());
    }

    #[test]
    fn residual_eval_matches_train_forward_value() {
        // eval (retain = false) must still consume the skip buffers:
        // identical logits path to the training forward
        let mut t = make("resnete_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 16 * 16 * 3, 10, 13);
        let (le, _) = t.eval(&x, &y).unwrap();
        let (lt, _) = t.train_step(&x, &y, 0.0).unwrap();
        // lr = 0 still updates optimizer state but the forward ran on
        // the same weights — losses must agree exactly
        assert_eq!(le, lt);
    }

    #[test]
    fn snapshot_roundtrip() {
        let mut a = make("mlp_mini", 8, Accel::Blocked);
        let mut b = make("mlp_mini", 8, Accel::Blocked);
        let (x, y) = toy_batch(8, 64, 10, 8);
        a.train_step(&x, &y, 0.01).unwrap();
        b.load_weights(&a.weights_snapshot()).unwrap();
        let (la, _) = a.eval(&x, &y).unwrap();
        let (lb, _) = b.eval(&x, &y).unwrap();
        assert!((la - lb).abs() < 1e-6);
    }
}
