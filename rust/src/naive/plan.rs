//! Execution plan: a [`Graph`] specialized to concrete conv geometry
//! for the naive engines (stride-1 SAME convs + 2×2 max-pool + dense,
//! matching the models the paper's prototype ran: MLP and the
//! BinaryNet/CNV family).

use anyhow::{bail, Result};

use crate::models::{Graph, LayerKind, Node};

#[derive(Clone, Debug)]
pub enum LayerPlan {
    Dense {
        k: usize,
        n: usize,
        first: bool,
    },
    /// 3×3 (or kxk) stride-1 SAME conv as im2col GEMM geometry.
    Conv {
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        kside: usize,
        first: bool,
    },
    MaxPool {
        h: usize,
        w: usize,
        c: usize,
    },
    Flatten,
}

impl LayerPlan {
    pub fn weight_len(&self) -> usize {
        match self {
            LayerPlan::Dense { k, n, .. } => k * n,
            LayerPlan::Conv { cin, cout, kside, .. } => kside * kside * cin * cout,
            _ => 0,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            LayerPlan::Dense { n, .. } => *n,
            LayerPlan::Conv { cout, .. } => *cout,
            _ => 0,
        }
    }

    pub fn fan_in(&self) -> usize {
        match self {
            LayerPlan::Dense { k, .. } => *k,
            LayerPlan::Conv { cin, kside, .. } => kside * kside * cin,
            _ => 0,
        }
    }

    /// Per-sample output elements.
    pub fn out_elems(&self) -> usize {
        match self {
            LayerPlan::Dense { n, .. } => *n,
            LayerPlan::Conv { h, w, cout, .. } => h * w * cout,
            LayerPlan::MaxPool { h, w, c } => (h / 2) * (w / 2) * c,
            LayerPlan::Flatten => 0,
        }
    }

    /// Per-sample input elements.
    pub fn in_elems(&self) -> usize {
        match self {
            LayerPlan::Dense { k, .. } => *k,
            LayerPlan::Conv { h, w, cin, .. } => h * w * cin,
            LayerPlan::MaxPool { h, w, c } => h * w * c,
            LayerPlan::Flatten => 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Plan {
    pub name: String,
    pub input_elems: usize,
    pub classes: usize,
    pub layers: Vec<LayerPlan>,
}

impl Plan {
    /// Build from a lowered graph.  Residual models are not supported
    /// by the naive engines (the paper's prototype ran MLP and
    /// BinaryNet only); use the HLO path for those.
    pub fn from_graph(graph: &Graph) -> Result<Plan> {
        let mut layers = Vec::new();
        // reconstruct spatial dims by walking nodes
        for node in &graph.nodes {
            match node.kind {
                LayerKind::Dense => layers.push(LayerPlan::Dense {
                    k: node.fan_in,
                    n: node.channels,
                    first: node.first,
                }),
                LayerKind::Conv => {
                    if node.in_residual {
                        bail!(
                            "naive engines do not support residual models \
                             ({}); use the HLO runtime",
                            graph.name
                        );
                    }
                    // SAME stride-1: out positions == in positions
                    let (pos, k, cout) = node.gemm;
                    if node.out_elems != pos * cout || pos * k / k != pos {
                        bail!("non-SAME conv in '{}' unsupported by naive engine", graph.name);
                    }
                    let (h, w) = square_of(pos)?;
                    let cin = node.in_elems / (h * w);
                    if cin * h * w != node.in_elems {
                        bail!("conv geometry mismatch in '{}'", graph.name);
                    }
                    let kside = isqrt(k / cin)?;
                    // pad = (kside-1)/2 is only a symmetric SAME
                    // padding for odd kernels — an even kside would
                    // silently under-pad the right/bottom edge and
                    // produce wrong geometry in every im2col/col2im
                    if kside == 0 || kside % 2 == 0 {
                        bail!(
                            "conv kernel side {kside} in '{}' unsupported: SAME \
                             geometry requires an odd kernel (pad = (kside-1)/2 \
                             would be asymmetric)",
                            graph.name
                        );
                    }
                    layers.push(LayerPlan::Conv { h, w, cin, cout, kside, first: node.first });
                }
                LayerKind::MaxPool => {
                    let c = prev_channels(&layers, node)?;
                    let (h, w) = square_of(node.in_elems / c)?;
                    layers.push(LayerPlan::MaxPool { h, w, c });
                }
                LayerKind::Flatten => layers.push(LayerPlan::Flatten),
                LayerKind::GlobalPool | LayerKind::ResidualMarker => {
                    bail!("layer {:?} unsupported by naive engine", node.kind)
                }
            }
        }
        Ok(Plan {
            name: graph.name.clone(),
            input_elems: graph.input_elems,
            classes: graph.classes,
            layers,
        })
    }
}

fn prev_channels(layers: &[LayerPlan], _node: &Node) -> Result<usize> {
    for l in layers.iter().rev() {
        let c = l.channels();
        if c > 0 {
            return Ok(c);
        }
    }
    bail!("max-pool before any conv layer is unsupported")
}

fn square_of(n: usize) -> Result<(usize, usize)> {
    let s = isqrt(n)?;
    Ok((s, s))
}

fn isqrt(n: usize) -> Result<usize> {
    let s = (n as f64).sqrt().round() as usize;
    if s * s != n {
        bail!("{n} is not a perfect square (non-square spatial dims unsupported)");
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    #[test]
    fn mlp_plan() {
        let g = lower(&get("mlp").unwrap()).unwrap();
        let p = Plan::from_graph(&g).unwrap();
        assert_eq!(p.layers.len(), 5);
        assert!(matches!(p.layers[0], LayerPlan::Dense { k: 784, n: 256, first: true }));
        assert!(matches!(p.layers[4], LayerPlan::Dense { k: 256, n: 10, first: false }));
    }

    #[test]
    fn binarynet_mini_plan() {
        let g = lower(&get("binarynet_mini").unwrap()).unwrap();
        let p = Plan::from_graph(&g).unwrap();
        // conv,conv,pool,conv,conv,pool,flatten,fc,fc,fc
        assert_eq!(p.layers.len(), 10);
        match p.layers[0] {
            LayerPlan::Conv { h: 16, w: 16, cin: 3, cout: 16, kside: 3, first: true } => {}
            ref other => panic!("{other:?}"),
        }
        match p.layers[2] {
            LayerPlan::MaxPool { h: 16, w: 16, c: 16 } => {}
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn residuals_rejected() {
        let g = lower(&get("resnete_mini").unwrap()).unwrap();
        assert!(Plan::from_graph(&g).is_err());
    }

    #[test]
    fn even_kside_rejected_at_plan_build() {
        // pad = (kside-1)/2 would silently produce asymmetric SAME
        // geometry for even kernels — plan building must refuse
        use crate::models::{LayerSpec, ModelSpec};
        for kernel in [2usize, 4] {
            let spec = ModelSpec {
                name: format!("even_k{kernel}"),
                input_shape: vec![8, 8, 3],
                classes: 10,
                layers: vec![
                    LayerSpec::conv(4, kernel).as_first(),
                    LayerSpec::flatten(),
                    LayerSpec::dense(10),
                ],
            };
            let g = lower(&spec).unwrap();
            let err = Plan::from_graph(&g).unwrap_err().to_string();
            assert!(err.contains("odd kernel"), "k={kernel}: {err}");
        }
        // odd kernels still build
        let spec = ModelSpec {
            name: "odd_k5".into(),
            input_shape: vec![8, 8, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 5).as_first(),
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let g = lower(&spec).unwrap();
        assert!(Plan::from_graph(&g).is_ok());
    }

    #[test]
    fn weight_lens_match_graph() {
        for m in ["mlp", "binarynet_mini", "cnv_mini", "binarynet"] {
            let g = lower(&get(m).unwrap()).unwrap();
            let p = Plan::from_graph(&g).unwrap();
            let total: usize = p.layers.iter().map(|l| l.weight_len()).sum();
            assert_eq!(total, g.total_weights(), "{m}");
        }
    }
}
