//! Execution plan: a [`Graph`] specialized to concrete layer geometry
//! for the naive engines.  Since PR 4 this is a *general* layer-graph
//! plan: strided and VALID convs (explicit [`ConvGeom`] derived from
//! the lowered nodes, never re-inferred by isqrt), validated 2×2
//! max-pools, global average pools, and residual skip markers — every
//! zoo model, including the CNV family and the full/mini residual
//! nets, builds a plan and trains.

use anyhow::{bail, Result};

use crate::bitops::ConvGeom;
use crate::models::{Graph, LayerKind, Padding};

/// Residual skip geometry: the saved block-input map (`h × w × c`)
/// and the block-output map (`oh × ow × co`) it is added to.  The
/// downsample shortcut is parameter-free: a strided 1×1 average pool
/// (spatial subsample at `stride`) plus channel duplication (output
/// channel `co` reads input channel `co mod c` — the ResNetE
/// concat-doubling expansion; identity when `co == c`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SkipGeom {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub oh: usize,
    pub ow: usize,
    pub co: usize,
    pub stride: usize,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerPlan {
    Dense {
        k: usize,
        n: usize,
        first: bool,
    },
    /// Conv as im2col GEMM geometry — any stride, SAME or VALID,
    /// independent input/output spatial dims (see [`ConvGeom`]).
    Conv {
        g: ConvGeom,
        cout: usize,
        first: bool,
    },
    /// `kside`×`kside` stride-`stride` max-pool with *validated*
    /// geometry: inputs whose last rows/columns the floor formula
    /// would silently drop are rejected at plan build
    /// (`(dim − kside) % stride` must be 0 — for the classic 2×2
    /// stride-2 pool that is the old even-dims rule; a 3×3 stride-2
    /// pool covers odd inputs), and the output dims are stored
    /// explicitly.
    MaxPool {
        h: usize,
        w: usize,
        c: usize,
        oh: usize,
        ow: usize,
        kside: usize,
        stride: usize,
    },
    /// Global average pool: `h × w × c` → `c` per sample.
    GlobalPool {
        h: usize,
        w: usize,
        c: usize,
    },
    /// Residual block boundary.  `save = true` stores the incoming
    /// f32 map as the skip (emitted just before the block's first
    /// conv); `save = false` adds the downsampled skip to the block
    /// output (emitted just after the closing conv's batch norm).
    /// Both carry the same [`SkipGeom`].
    Residual {
        save: bool,
        skip: SkipGeom,
    },
    Flatten,
}

impl LayerPlan {
    pub fn weight_len(&self) -> usize {
        match self {
            LayerPlan::Dense { k, n, .. } => k * n,
            LayerPlan::Conv { g, cout, .. } => g.k() * cout,
            _ => 0,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            LayerPlan::Dense { n, .. } => *n,
            LayerPlan::Conv { cout, .. } => *cout,
            _ => 0,
        }
    }

    pub fn fan_in(&self) -> usize {
        match self {
            LayerPlan::Dense { k, .. } => *k,
            LayerPlan::Conv { g, .. } => g.k(),
            _ => 0,
        }
    }

    /// Per-sample output elements.
    pub fn out_elems(&self) -> usize {
        match self {
            LayerPlan::Dense { n, .. } => *n,
            LayerPlan::Conv { g, cout, .. } => g.oh * g.ow * cout,
            LayerPlan::MaxPool { oh, ow, c, .. } => oh * ow * c,
            LayerPlan::GlobalPool { c, .. } => *c,
            LayerPlan::Residual { .. } | LayerPlan::Flatten => 0,
        }
    }

    /// Per-sample input elements.
    pub fn in_elems(&self) -> usize {
        match self {
            LayerPlan::Dense { k, .. } => *k,
            LayerPlan::Conv { g, .. } => g.h * g.w * g.cin,
            LayerPlan::MaxPool { h, w, c, .. } => h * w * c,
            LayerPlan::GlobalPool { h, w, c } => h * w * c,
            LayerPlan::Residual { .. } | LayerPlan::Flatten => 0,
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Plan {
    pub name: String,
    pub input_elems: usize,
    pub classes: usize,
    pub layers: Vec<LayerPlan>,
}

impl Plan {
    /// Number of weight-carrying (matmul) layers.
    pub fn weight_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.weight_len() > 0).count()
    }

    /// Build from a lowered graph, validating every geometry the
    /// engines rely on: SAME convs need odd kernels, VALID kernels
    /// must fit the map, max-pool inputs must be even, residual skip
    /// shapes must admit the parameter-free downsample shortcut.
    pub fn from_graph(graph: &Graph) -> Result<Plan> {
        let mut layers = Vec::new();
        // (index of the pending Residual-save entry, h, w, c,
        // accumulated block stride).  The shortcut subsample stride is
        // the *product* of the block convs' strides — recorded, never
        // re-inferred from the spatial ratio (which can pick a
        // different subsample grid than the conv path for stride ≥ 3
        // on small odd maps).
        let mut open: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
        for node in &graph.nodes {
            match node.kind {
                LayerKind::Dense => layers.push(LayerPlan::Dense {
                    k: node.fan_in,
                    n: node.channels,
                    first: node.first,
                }),
                LayerKind::Conv => {
                    let ng = node
                        .geom
                        .ok_or_else(|| anyhow::anyhow!("conv node without geometry"))?;
                    let g = match ng.pad {
                        Padding::Same => {
                            // pad = (kside-1)/2 is only a symmetric SAME
                            // padding for odd kernels — an even kside
                            // would silently under-pad one edge and
                            // produce wrong geometry in every
                            // im2col/col2im
                            if ng.kside == 0 || ng.kside % 2 == 0 {
                                bail!(
                                    "conv kernel side {} in '{}' unsupported: SAME \
                                     geometry requires an odd kernel (pad = (kside-1)/2 \
                                     would be asymmetric)",
                                    ng.kside,
                                    graph.name
                                );
                            }
                            ConvGeom::same(ng.h, ng.w, ng.c_in, ng.kside, ng.stride)
                        }
                        Padding::Valid => {
                            ConvGeom::valid(ng.h, ng.w, ng.c_in, ng.kside, ng.stride)
                        }
                    };
                    if (g.oh, g.ow) != (ng.oh, ng.ow)
                        || node.out_elems != g.oh * g.ow * node.channels
                    {
                        bail!("conv geometry mismatch in '{}'", graph.name);
                    }
                    if node.skip_open {
                        if !open.is_empty() {
                            // stride compounding below assumes strictly
                            // sequential blocks (what lowering emits);
                            // nesting would silently mis-stride the
                            // outer shortcut
                            bail!("nested residual blocks in '{}' unsupported", graph.name);
                        }
                        open.push((layers.len(), ng.h, ng.w, ng.c_in, 1));
                        // geometry patched when the block closes
                        layers.push(LayerPlan::Residual {
                            save: true,
                            skip: SkipGeom {
                                h: ng.h,
                                w: ng.w,
                                c: ng.c_in,
                                oh: ng.h,
                                ow: ng.w,
                                co: ng.c_in,
                                stride: 1,
                            },
                        });
                    }
                    if let Some(top) = open.last_mut() {
                        // this conv executes inside the open block:
                        // its stride compounds into the shortcut's
                        top.4 *= ng.stride;
                    }
                    layers.push(LayerPlan::Conv { g, cout: node.channels, first: node.first });
                    if node.skip_close {
                        let (si, h, w, c, stride) = open.pop().ok_or_else(|| {
                            anyhow::anyhow!("residual close without open in '{}'", graph.name)
                        })?;
                        let (oh, ow, co) = (ng.oh, ng.ow, node.channels);
                        if oh == 0
                            || h.div_ceil(stride) != oh
                            || w.div_ceil(stride) != ow
                            || (oh - 1) * stride >= h
                            || (ow - 1) * stride >= w
                            || co == 0
                            || co % c != 0
                        {
                            bail!(
                                "residual skip {h}x{w}x{c} -> {oh}x{ow}x{co} in '{}' \
                                 unsupported: shortcut needs out = ceil(in/stride) \
                                 spatially and channel duplication (co % c == 0)",
                                graph.name
                            );
                        }
                        let skip = SkipGeom { h, w, c, oh, ow, co, stride };
                        layers[si] = LayerPlan::Residual { save: true, skip };
                        layers.push(LayerPlan::Residual { save: false, skip });
                    }
                }
                LayerKind::MaxPool => {
                    let ng = node
                        .geom
                        .ok_or_else(|| anyhow::anyhow!("pool node without geometry"))?;
                    let (kside, stride) = (ng.kside, ng.stride);
                    if kside == 0 || stride == 0 || kside > ng.h || kside > ng.w {
                        bail!(
                            "{kside}x{kside} stride-{stride} max-pool does not fit the \
                             {}x{} map in '{}'",
                            ng.h,
                            ng.w,
                            graph.name
                        );
                    }
                    if (ng.h - kside) % stride != 0 || (ng.w - kside) % stride != 0 {
                        bail!(
                            "{kside}x{kside} stride-{stride} max-pool input {}x{} in '{}' \
                             has uncovered dims: the floor output would silently drop \
                             the last rows/columns ((dim - kside) % stride must be 0)",
                            ng.h,
                            ng.w,
                            graph.name
                        );
                    }
                    if (ng.oh, ng.ow)
                        != ((ng.h - kside) / stride + 1, (ng.w - kside) / stride + 1)
                    {
                        bail!("max-pool geometry mismatch in '{}'", graph.name);
                    }
                    layers.push(LayerPlan::MaxPool {
                        h: ng.h,
                        w: ng.w,
                        c: ng.c_in,
                        oh: ng.oh,
                        ow: ng.ow,
                        kside,
                        stride,
                    });
                }
                LayerKind::GlobalPool => {
                    let ng = node
                        .geom
                        .ok_or_else(|| anyhow::anyhow!("pool node without geometry"))?;
                    layers.push(LayerPlan::GlobalPool { h: ng.h, w: ng.w, c: ng.c_in });
                }
                LayerKind::Flatten => layers.push(LayerPlan::Flatten),
                LayerKind::ResidualMarker => {
                    // lowering expands markers into convs with
                    // skip_open/skip_close; a surviving marker is a bug
                    bail!("unexpanded residual marker in '{}'", graph.name)
                }
            }
        }
        if !open.is_empty() {
            bail!("unclosed residual block in '{}'", graph.name);
        }
        Ok(Plan {
            name: graph.name.clone(),
            input_elems: graph.input_elems,
            classes: graph.classes,
            layers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower, LayerSpec, ModelSpec};

    #[test]
    fn mlp_plan() {
        let g = lower(&get("mlp").unwrap()).unwrap();
        let p = Plan::from_graph(&g).unwrap();
        assert_eq!(p.layers.len(), 5);
        assert!(matches!(p.layers[0], LayerPlan::Dense { k: 784, n: 256, first: true }));
        assert!(matches!(p.layers[4], LayerPlan::Dense { k: 256, n: 10, first: false }));
    }

    #[test]
    fn binarynet_mini_plan() {
        let g = lower(&get("binarynet_mini").unwrap()).unwrap();
        let p = Plan::from_graph(&g).unwrap();
        // conv,conv,pool,conv,conv,pool,flatten,fc,fc,fc
        assert_eq!(p.layers.len(), 10);
        match p.layers[0] {
            LayerPlan::Conv { g, cout: 16, first: true }
                if (g.h, g.w, g.cin, g.kside, g.stride) == (16, 16, 3, 3, 1)
                    && g.unit() => {}
            ref other => panic!("{other:?}"),
        }
        match p.layers[2] {
            LayerPlan::MaxPool { h: 16, w: 16, c: 16, oh: 8, ow: 8, kside: 2, stride: 2 } => {}
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn residual_minis_plan_with_skip_markers() {
        for (name, convs_per_block) in [("resnete_mini", 2usize), ("bireal_mini", 1)] {
            let g = lower(&get(name).unwrap()).unwrap();
            let p = Plan::from_graph(&g).unwrap();
            let saves: Vec<usize> = p
                .layers
                .iter()
                .enumerate()
                .filter_map(|(i, l)| {
                    matches!(l, LayerPlan::Residual { save: true, .. }).then_some(i)
                })
                .collect();
            let adds = p
                .layers
                .iter()
                .filter(|l| matches!(l, LayerPlan::Residual { save: false, .. }))
                .count();
            assert_eq!(saves.len(), 4, "{name}");
            assert_eq!(adds, 4, "{name}");
            // each save is immediately followed by its block's convs
            // and then the matching add
            for &si in &saves {
                for j in 1..=convs_per_block {
                    assert!(
                        matches!(p.layers[si + j], LayerPlan::Conv { .. }),
                        "{name} @ {si}+{j}"
                    );
                }
                match p.layers[si + convs_per_block + 1] {
                    LayerPlan::Residual { save: false, skip } => {
                        assert_eq!(skip.stride, 1, "{name}");
                        assert!(skip.co == skip.c || skip.co == 2 * skip.c, "{name}");
                    }
                    ref other => panic!("{name}: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn full_residual_models_plan_with_strided_shortcuts() {
        for name in ["resnete18", "bireal18"] {
            let g = lower(&get(name).unwrap()).unwrap();
            let p = Plan::from_graph(&g).unwrap();
            // stage-entry blocks downsample 2x spatially and double
            // channels; the shortcut geometry must record both
            let strided: Vec<&SkipGeom> = p
                .layers
                .iter()
                .filter_map(|l| match l {
                    LayerPlan::Residual { save: false, skip } if skip.stride == 2 => Some(skip),
                    _ => None,
                })
                .collect();
            assert_eq!(strided.len(), 3, "{name}"); // stages 2, 3, 4
            for s in strided {
                assert_eq!(s.h, s.oh * 2, "{name}");
                assert_eq!(s.co, s.c * 2, "{name}");
            }
            // global pool present with the final 7x7x512 map
            assert!(
                p.layers
                    .iter()
                    .any(|l| matches!(l, LayerPlan::GlobalPool { h: 7, w: 7, c: 512 })),
                "{name}"
            );
        }
    }

    #[test]
    fn residual_shortcut_stride_is_recorded_not_inferred() {
        // stride-4 block on a 5x5 map: oh = ceil(5/4) = 2.  Inferring
        // the shortcut stride from the spatial ratio would pick
        // ceil(5/2) = 3 — which also satisfies ceil(5/3) = 2 but
        // subsamples rows {0,3} while the conv path samples {0,4}.
        // The plan must carry the block convs' recorded stride.
        let spec = ModelSpec {
            name: "s4_resid".into(),
            input_shape: vec![5, 5, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 3).as_first(),
                LayerSpec::residual(8, 3, 4, true), // bireal single conv, s4
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let p = Plan::from_graph(&lower(&spec).unwrap()).unwrap();
        let skip = p
            .layers
            .iter()
            .find_map(|l| match l {
                LayerPlan::Residual { save: false, skip } => Some(*skip),
                _ => None,
            })
            .unwrap();
        assert_eq!(skip.stride, 4, "{skip:?}");
        assert_eq!((skip.h, skip.oh), (5, 2));
        // a two-conv block compounds its convs' strides
        let spec = ModelSpec {
            name: "s2_two_conv".into(),
            input_shape: vec![8, 8, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 3).as_first(),
                LayerSpec::residual(8, 3, 2, false), // resnete: s2 then s1
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let p = Plan::from_graph(&lower(&spec).unwrap()).unwrap();
        let skip = p
            .layers
            .iter()
            .find_map(|l| match l {
                LayerPlan::Residual { save: false, skip } => Some(*skip),
                _ => None,
            })
            .unwrap();
        assert_eq!((skip.stride, skip.h, skip.oh), (2, 8, 4));
    }

    #[test]
    fn cnv_valid_plan() {
        let g = lower(&get("cnv").unwrap()).unwrap();
        let p = Plan::from_graph(&g).unwrap();
        let convs: Vec<&ConvGeom> = p
            .layers
            .iter()
            .filter_map(|l| match l {
                LayerPlan::Conv { g, .. } => Some(g),
                _ => None,
            })
            .collect();
        assert_eq!(convs.len(), 6);
        // 32 -(3x3 VALID)-> 30 -> 28 -pool-> 14 -> 12 -> 10 -pool-> 5 -> 3 -> 1
        assert_eq!((convs[0].h, convs[0].oh), (32, 30));
        assert!(!convs[0].padded());
        assert_eq!((convs[5].h, convs[5].oh), (3, 1));
    }

    #[test]
    fn even_kside_rejected_at_plan_build() {
        // pad = (kside-1)/2 would silently produce asymmetric SAME
        // geometry for even kernels — plan building must refuse
        for kernel in [2usize, 4] {
            let spec = ModelSpec {
                name: format!("even_k{kernel}"),
                input_shape: vec![8, 8, 3],
                classes: 10,
                layers: vec![
                    LayerSpec::conv(4, kernel).as_first(),
                    LayerSpec::flatten(),
                    LayerSpec::dense(10),
                ],
            };
            let g = lower(&spec).unwrap();
            let err = Plan::from_graph(&g).unwrap_err().to_string();
            assert!(err.contains("odd kernel"), "k={kernel}: {err}");
        }
        // odd kernels still build
        let spec = ModelSpec {
            name: "odd_k5".into(),
            input_shape: vec![8, 8, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 5).as_first(),
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let g = lower(&spec).unwrap();
        assert!(Plan::from_graph(&g).is_ok());
    }

    #[test]
    fn uncovered_pool_input_rejected_at_plan_build() {
        // 5x5 input into a 2x2 stride-2 pool would silently drop a
        // row/column ((5-2) % 2 != 0)
        let spec = ModelSpec {
            name: "odd_pool".into(),
            input_shape: vec![5, 5, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 3).as_first(),
                LayerSpec::maxpool(),
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let g = lower(&spec).unwrap();
        let err = Plan::from_graph(&g).unwrap_err().to_string();
        assert!(err.contains("uncovered dims"), "{err}");
        // even dims still build
        let spec = ModelSpec {
            name: "even_pool".into(),
            input_shape: vec![6, 6, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 3).as_first(),
                LayerSpec::maxpool(),
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        assert!(Plan::from_graph(&lower(&spec).unwrap()).is_ok());
    }

    #[test]
    fn general_pool_geometry_validated_at_plan_build() {
        let with_pool = |hw: usize, kside: usize, stride: usize| ModelSpec {
            name: format!("pool_{hw}_{kside}_{stride}"),
            input_shape: vec![hw, hw, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 3).as_first(),
                LayerSpec::maxpool_k(kside, stride),
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        // a 3x3 stride-2 pool covers odd inputs the 2x2 pool rejects
        let p = Plan::from_graph(&lower(&with_pool(7, 3, 2)).unwrap()).unwrap();
        match p.layers[1] {
            LayerPlan::MaxPool { h: 7, w: 7, c: 4, oh: 3, ow: 3, kside: 3, stride: 2 } => {}
            ref other => panic!("{other:?}"),
        }
        // overlapping 3x3 stride-1 builds too (out = in - 2)
        let p = Plan::from_graph(&lower(&with_pool(6, 3, 1)).unwrap()).unwrap();
        assert!(matches!(
            p.layers[1],
            LayerPlan::MaxPool { h: 6, oh: 4, kside: 3, stride: 1, .. }
        ));
        // 3x3 stride-2 on an even map drops the last row/column
        let err = Plan::from_graph(&lower(&with_pool(8, 3, 2)).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("uncovered dims"), "{err}");
        // kernel larger than the map is rejected at lowering
        assert!(lower(&with_pool(4, 6, 1)).is_err());
    }

    #[test]
    fn weight_lens_match_graph() {
        for m in crate::models::names() {
            let g = lower(&get(m).unwrap()).unwrap();
            let p = Plan::from_graph(&g).unwrap();
            let total: usize = p.layers.iter().map(|l| l.weight_len()).sum();
            assert_eq!(total, g.total_weights(), "{m}");
            assert_eq!(
                p.weight_layers(),
                g.nodes.iter().filter(|n| n.w_elems > 0).count(),
                "{m}"
            );
        }
    }
}
