//! `PackedInferEngine`: the forward-only execution engine.
//!
//! Lowers a [`Plan`] into an inference schedule that drives the same
//! fused kernel pipeline as the trainers — `im2col_packed` bit
//! panels, XNOR-popcount GEMM on the selected [`Accel`] tier, the
//! masked padding correction — but retains *nothing*: no activations,
//! no STE masks, no BN residuals, no gradient transients.  Every
//! transient is a [`StepArena`] checkout that returns within the same
//! layer, so after [`PackedInferEngine::warmup`] a forward pass at
//! *any* batch size ≤ `max_batch` performs **zero heap allocations**
//! (hard-asserted via `memtrack::alloc_count` in rust/tests/).
//!
//! ## Bit-exactness
//!
//! `forward_standard` / `forward_proposed` mirror the corresponding
//! trainer's `matmul_forward` branch structure *exactly* — same
//! kernels, same operand order, same per-tier dispatch — with the
//! packed weights read from an immutable [`WeightSnapshot`] instead
//! of the trainer's per-step cache.  The snapshot packs the same bits
//! the trainers pack (see `serve::snapshot`), so logits are
//! bit-identical to `StandardTrainer::eval` / `ProposedTrainer::eval`
//! on the same tier and batch (rust/tests/serve_parity.rs pins this
//! for every zoo model).
//!
//! DRIFT WARNING: if a trainer forward branch changes, this engine
//! (and the serve modes of `naive::schedule`) must change with it —
//! the parity tests catch any divergence, and the schedule executor
//! panics on the first mismatched arena event.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::snapshot::WeightSnapshot;
use crate::bitops::im2col::conv_fwd_first_streaming_into;
use crate::bitops::{im2col_packed_into, subtract_pad_contrib_with, BitMatrix};
use crate::naive::arena::StepCtx;
use crate::naive::ops::{self, EngineOps};
use crate::naive::schedule::{self, StepSchedule};
use crate::naive::{
    bn_l1_forward_packed_into, bn_l2_forward_into, conv_direct_into, maxpool_forward_into,
    sign_into, softmax_xent_grad, Accel, LayerPlan, Plan,
};
use crate::models::Graph;

/// Which training algorithm's forward numerics to replicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferAlgo {
    /// Algorithm 1 forward: ℓ2 batch norm.
    Standard,
    /// Algorithm 2 forward: ℓ1 + BNN-specific batch norm.
    Proposed,
}

impl InferAlgo {
    pub fn parse(s: &str) -> Result<InferAlgo> {
        Ok(match s {
            "standard" => InferAlgo::Standard,
            "proposed" => InferAlgo::Proposed,
            _ => bail!("unknown algo '{s}' (standard|proposed)"),
        })
    }
}

/// Forward-only packed inference engine (see module docs).
pub struct PackedInferEngine {
    plan: Plan,
    algo: InferAlgo,
    accel: Accel,
    max_batch: usize,
    /// Batch of the in-flight forward (`EngineOps::micro`).
    cur: usize,
    snap: Arc<WeightSnapshot>,
    /// Compiled serve schedule: one infer + one eval pass per batch
    /// size `1..=max_batch`, slot-colored across all of them.
    sched: Arc<StepSchedule>,
    ctx: StepCtx,
}

impl PackedInferEngine {
    /// Build an engine for `graph` serving `snap` (shapes validated).
    pub fn new(
        graph: &Graph,
        algo: InferAlgo,
        accel: Accel,
        max_batch: usize,
        snap: Arc<WeightSnapshot>,
    ) -> Result<PackedInferEngine> {
        let plan = Plan::from_graph(graph)?;
        if max_batch == 0 {
            bail!("max_batch must be positive");
        }
        if !snap.matches(&plan) {
            bail!("weight snapshot does not match plan '{}'", plan.name);
        }
        let algo_name = match algo {
            InferAlgo::Standard => "standard",
            InferAlgo::Proposed => "proposed",
        };
        let sched = Arc::new(schedule::compile_serve(
            &plan,
            algo_name,
            accel == Accel::Naive,
            max_batch,
        )?);
        let mut ctx = StepCtx::default();
        ctx.arena.install(&sched.slots);
        Ok(PackedInferEngine {
            plan,
            algo,
            accel,
            max_batch,
            cur: 0,
            snap,
            sched,
            ctx,
        })
    }

    /// The compiled serve schedule this engine executes.
    pub fn schedule(&self) -> &Arc<StepSchedule> {
        &self.sched
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn classes(&self) -> usize {
        self.plan.classes
    }

    pub fn input_elems(&self) -> usize {
        self.plan.input_elems
    }

    pub fn algo(&self) -> InferAlgo {
        self.algo
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// True when the inference arena is quiescent (no pass active,
    /// every slot parked) — asserted by the multi-tenant runtime at
    /// preemption boundaries.
    pub fn arena_idle(&self) -> bool {
        self.ctx.arena.idle()
    }

    /// The snapshot currently serving.
    pub fn snapshot(&self) -> &Arc<WeightSnapshot> {
        &self.snap
    }

    /// Bytes resident in the scratch arena (the whole per-request
    /// transient footprint after warmup).
    pub fn arena_bytes(&self) -> usize {
        self.ctx.arena.heap_bytes()
    }

    /// Bytes of the installed snapshot (packed w + wt + β).
    pub fn state_bytes(&self) -> usize {
        self.snap.heap_bytes()
    }

    /// Swap in a newly published snapshot (copy-on-publish: the old
    /// `Arc` is returned and stays valid for anyone still holding
    /// it).  Shape-checked; allocation-free beyond the `Arc` swap.
    pub fn install(&mut self, snap: Arc<WeightSnapshot>) -> Result<Arc<WeightSnapshot>> {
        if !snap.matches(&self.plan) {
            bail!("published snapshot does not match plan '{}'", self.plan.name);
        }
        Ok(std::mem::replace(&mut self.snap, snap))
    }

    /// Forward one batch: `x` is `batch × input_elems` NHWC, `logits`
    /// receives `batch × classes`.  Allocation-free after
    /// [`PackedInferEngine::warmup`].
    pub fn infer_into(&mut self, x: &[f32], batch: usize, logits: &mut [f32]) -> Result<()> {
        let out = self.forward(x, batch, false)?;
        logits.copy_from_slice(&out);
        self.ctx.arena.put_f32(out);
        self.ctx.arena.end_pass();
        Ok(())
    }

    /// Forward + softmax cross-entropy: returns (loss, accuracy),
    /// numerically identical to the trainers' `eval` on the same
    /// batch and tier (single-chunk).  Allocation-free after warmup.
    pub fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)> {
        let logits = self.forward(x, labels.len(), true)?;
        let mut d = self.ctx.arena.take_f32(labels.len() * self.plan.classes);
        let (loss, acc) = softmax_xent_grad(&logits, labels, self.plan.classes, &mut d);
        self.ctx.arena.put_f32(logits);
        self.ctx.arena.put_f32(d);
        self.ctx.arena.end_pass();
        Ok((loss, acc))
    }

    /// Exercise one forward at every batch size `max_batch..=1`.
    /// Since the schedule executor pre-allocates every colored slot
    /// at install, the arena is at its fixed point from construction;
    /// warmup survives as a smoke pass over all batch-size schedules
    /// (and keeps the serving call sites' warmup discipline honest).
    pub fn warmup(&mut self) -> Result<()> {
        let mut x = vec![0.0f32; self.max_batch * self.plan.input_elems];
        for (i, v) in x.iter_mut().enumerate() {
            // ±1 checkerboard: exercises both BN sign branches
            *v = if i % 2 == 0 { 1.0 } else { -1.0 };
        }
        let mut logits = vec![0.0f32; self.max_batch * self.plan.classes];
        for b in (1..=self.max_batch).rev() {
            self.infer_into(
                &x[..b * self.plan.input_elems],
                b,
                &mut logits[..b * self.plan.classes],
            )?;
        }
        Ok(())
    }

    /// Forward under the batch's scheduled pass (`eval` selects the
    /// eval-pass variant, whose post-forward events include the
    /// softmax gradient scratch).  On success the pass is left active
    /// for the caller's final puts + `end_pass`; on error it is
    /// aborted here.
    fn forward(&mut self, x: &[f32], batch: usize, eval: bool) -> Result<Vec<f32>> {
        if batch == 0 || batch > self.max_batch {
            bail!("batch {batch} outside 1..={}", self.max_batch);
        }
        if x.len() != batch * self.plan.input_elems {
            bail!(
                "input is {} elems, want {} x {}",
                x.len(),
                batch,
                self.plan.input_elems
            );
        }
        self.cur = batch;
        // hygiene after an aborted forward (no-op in steady state)
        self.ctx.drain_skip_stacks();
        let sched = self.sched.clone();
        let pass = if eval {
            sched.serve_eval_pass(batch)
        } else {
            sched.infer_pass(batch)
        };
        self.ctx.arena.begin_pass(pass.clone());
        let r = ops::forward_plan(self, &sched.fwd_ops, x, false);
        if r.is_err() {
            self.ctx.arena.abort_pass();
        }
        r
    }

    /// Algorithm 1 forward branch structure (StandardTrainer
    /// `matmul_forward` with `retain = false`), weights off the
    /// snapshot.
    fn forward_standard(&mut self, cur: Vec<f32>, wi: usize, layer: &LayerPlan) -> Result<Vec<f32>> {
        let b = self.cur;
        let (y, rows, n) = match *layer {
            LayerPlan::Dense { k, n, first } => {
                let mut y = self.ctx.arena.take_f32(b * n);
                if first || self.accel == Accel::Naive {
                    let mut bw = self.ctx.arena.take_f32(k * n);
                    self.snap.layer(wi).w.unpack_into(&mut bw);
                    if first {
                        self.accel.backend().gemm_f32(b, k, n, &cur, &bw, &mut y);
                    } else {
                        let mut a = self.ctx.arena.take_f32(cur.len());
                        sign_into(&cur, &mut a);
                        self.accel.backend().gemm_f32(b, k, n, &a, &bw, &mut y);
                        self.ctx.arena.put_f32(a);
                    }
                    self.ctx.arena.put_f32(bw);
                } else {
                    let mut xhat = self.ctx.arena.take_bits(b, k);
                    BitMatrix::pack_into(b, k, &cur, &mut xhat);
                    self.accel
                        .backend()
                        .xnor_gemm(&xhat, &self.snap.layer(wi).wt, &mut y);
                    self.ctx.arena.put_bits(xhat);
                }
                (y, b, n)
            }
            LayerPlan::Conv { g, cout, first } => {
                let rows = g.rows(b);
                let mut y;
                if first || self.accel == Accel::Naive {
                    let mut bw = self.ctx.arena.take_f32(g.k() * cout);
                    self.snap.layer(wi).w.unpack_into(&mut bw);
                    if self.accel == Accel::Naive {
                        y = self.ctx.arena.take_zeroed_f32(rows * cout);
                        if first {
                            conv_direct_into(&cur, &bw, b, g, cout, &mut y);
                        } else {
                            let mut a = self.ctx.arena.take_f32(cur.len());
                            sign_into(&cur, &mut a);
                            conv_direct_into(&a, &bw, b, g, cout, &mut y);
                            self.ctx.arena.put_f32(a);
                        }
                    } else {
                        // tap-streamed first conv mirroring the
                        // trainer's fused arm (bit-identical)
                        y = self.ctx.arena.take_f32(rows * cout);
                        let mut panel = self.ctx.arena.take_f32(rows * g.cin);
                        conv_fwd_first_streaming_into(
                            &cur,
                            &bw,
                            b,
                            g,
                            cout,
                            self.accel.backend(),
                            &mut y,
                            &mut panel,
                        );
                        self.ctx.arena.put_f32(panel);
                    }
                    self.ctx.arena.put_f32(bw);
                } else {
                    y = self.ctx.arena.take_f32(rows * cout);
                    let backend = self.accel.backend();
                    let mut xhat = self.ctx.arena.take_bits(rows, g.k());
                    im2col_packed_into(&cur, b, g, &backend.pool(), &mut xhat);
                    let wt = &self.snap.layer(wi).wt;
                    backend.xnor_gemm(&xhat, wt, &mut y);
                    let mut scratch = self.ctx.arena.take_f32(g.kside * g.kside * cout);
                    subtract_pad_contrib_with(&mut y, wt, b, g, &mut scratch);
                    self.ctx.arena.put_f32(scratch);
                    self.ctx.arena.put_bits(xhat);
                }
                (y, rows, cout)
            }
            _ => unreachable!("matmul_forward on a non-matmul layer"),
        };
        let mut xn = self.ctx.arena.take_f32(rows * n);
        let mut mu = self.ctx.arena.take_f32(n);
        let mut psi = self.ctx.arena.take_f32(n);
        bn_l2_forward_into(&y, rows, n, &self.snap.layer(wi).beta, &mut xn, &mut mu, &mut psi);
        self.ctx.arena.put_f32(y);
        self.ctx.arena.put_f32(cur);
        self.ctx.arena.put_f32(mu);
        self.ctx.arena.put_f32(psi);
        Ok(xn)
    }

    /// Algorithm 2 forward branch structure (ProposedTrainer
    /// `matmul_bn_forward` with `retain = false`), weights off the
    /// snapshot.  The STE mask is skipped entirely — it exists only
    /// for backward and does not touch the logits.
    fn forward_proposed(&mut self, cur: Vec<f32>, wi: usize, layer: &LayerPlan) -> Result<Vec<f32>> {
        let b = self.cur;
        let (rows, k, n, first, conv) = match *layer {
            LayerPlan::Dense { k, n, first } => (b, k, n, first, None),
            LayerPlan::Conv { g, cout, first } => (g.rows(b), g.k(), cout, first, Some(g)),
            _ => unreachable!("matmul_forward on a non-matmul layer"),
        };
        let y: Vec<f32>;
        if first {
            // real-input layer: f32 GEMM against sign(W)
            let backend = self.accel.backend();
            let mut w = self.ctx.arena.take_f32(k * n);
            self.snap.layer(wi).w.unpack_into(&mut w);
            y = match conv {
                None => {
                    let mut out = self.ctx.arena.take_f32(rows * n);
                    backend.gemm_f32(rows, k, n, &cur, &w, &mut out);
                    out
                }
                Some(g) => match self.accel {
                    Accel::Naive => {
                        let mut out = self.ctx.arena.take_zeroed_f32(rows * n);
                        conv_direct_into(&cur, &w, b, g, n, &mut out);
                        out
                    }
                    _ => {
                        // tap-streamed first conv mirroring the
                        // trainer's fused arm (bit-identical)
                        let mut out = self.ctx.arena.take_f32(rows * n);
                        let mut panel = self.ctx.arena.take_f32(rows * g.cin);
                        conv_fwd_first_streaming_into(
                            &cur, &w, b, g, n, backend, &mut out, &mut panel,
                        );
                        self.ctx.arena.put_f32(panel);
                        out
                    }
                },
            };
            self.ctx.arena.put_f32(w);
            self.ctx.arena.put_f32(cur);
        } else {
            // binary×binary: pack X̂, XNOR against the snapshot's Ŵᵀ
            // (no padding correction — matches the trainer)
            let mut xhat = self.ctx.arena.take_bits(rows, k);
            match conv {
                None => BitMatrix::pack_into(rows, k, &cur, &mut xhat),
                Some(g) => {
                    let pool = self.accel.backend().pool();
                    im2col_packed_into(&cur, b, g, &pool, &mut xhat);
                }
            }
            self.ctx.arena.put_f32(cur);
            let mut out = self.ctx.arena.take_f32(rows * n);
            self.accel
                .backend()
                .xnor_gemm(&xhat, &self.snap.layer(wi).wt, &mut out);
            y = out;
            self.ctx.arena.put_bits(xhat);
        }

        // ℓ1 batch norm; β straight off the snapshot (already f32)
        let mut x_next = self.ctx.arena.take_f32(rows * n);
        let mut psi = self.ctx.arena.take_f32(n);
        let mut omega = self.ctx.arena.take_f32(n);
        let mut mu = self.ctx.arena.take_f32(n);
        let mut sign = self.ctx.arena.take_zeroed_bits(rows, n);
        bn_l1_forward_packed_into(
            &y,
            rows,
            n,
            &self.snap.layer(wi).beta,
            &mut x_next,
            &mut psi,
            &mut omega,
            &mut mu,
            &mut sign,
        );
        self.ctx.arena.put_f32(y);
        self.ctx.arena.put_f32(psi);
        self.ctx.arena.put_f32(omega);
        self.ctx.arena.put_f32(mu);
        self.ctx.arena.put_bits(sign);
        Ok(x_next)
    }
}

impl EngineOps for PackedInferEngine {
    type Grad = Vec<f32>;

    fn micro(&self) -> usize {
        self.cur
    }

    fn ctx(&mut self) -> &mut StepCtx {
        &mut self.ctx
    }

    fn grad_to_f32(&mut self, g: Vec<f32>) -> Vec<f32> {
        g
    }

    fn grad_from_f32(&mut self, v: Vec<f32>) -> Vec<f32> {
        v
    }

    fn recycle_grad(&mut self, g: Vec<f32>) {
        self.ctx.arena.put_f32(g);
    }

    fn matmul_forward(
        &mut self,
        cur: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        _retain: bool,
    ) -> Result<Vec<f32>> {
        match self.algo {
            InferAlgo::Standard => self.forward_standard(cur, wi, layer),
            InferAlgo::Proposed => self.forward_proposed(cur, wi, layer),
        }
    }

    fn matmul_backward(
        &mut self,
        _dnext: Vec<f32>,
        _wi: usize,
        _layer: &LayerPlan,
    ) -> Result<Vec<f32>> {
        bail!("inference engine has no backward")
    }

    fn pool_forward(
        &mut self,
        cur: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
        _retain: bool,
    ) -> Vec<f32> {
        let b = self.cur;
        let (oh, ow) = crate::naive::pool_out_dims(h, w, kside, stride);
        let cells = b * oh * ow * c;
        let mut out = self.ctx.arena.take_f32(cells);
        let mut mask = self.ctx.arena.take_u32(cells);
        maxpool_forward_into(&cur, b, h, w, c, kside, stride, &mut out, &mut mask);
        self.ctx.arena.put_f32(cur);
        self.ctx.arena.put_u32(mask);
        out
    }

    fn pool_backward(
        &mut self,
        _dnext: Vec<f32>,
        _h: usize,
        _w: usize,
        _c: usize,
        _kside: usize,
        _stride: usize,
    ) -> Vec<f32> {
        unreachable!("inference engine has no backward")
    }

    fn end_chunk(&mut self) {}
}
