//! Copy-on-publish packed weight snapshots.
//!
//! A [`WeightSnapshot`] is the *immutable* inference-side image of a
//! model's weights: per matmul layer the bit-packed binarized Ŵ
//! (k×n), its word-transposed Ŵᵀ (n×k, what the XNOR GEMM consumes)
//! and the f32 BN shift β.  Snapshots are shared behind an `Arc`:
//! `publish` packs **once** from a trainer's `weights_snapshot()`
//! image, readers clone the `Arc`, and a training loop hot-swapping
//! weights never touches a snapshot an in-flight request still holds
//! — requests observe either the old weights or the new ones, never
//! a mix.
//!
//! Bit-exactness with the training engines is by construction:
//!
//! - `weights_snapshot()` returns *exact* f32 images of the latent
//!   weights (f16 stores widen losslessly), so packing here with
//!   [`BitMatrix::pack`] (`v >= 0.0` ⇒ +1, f32 `-0.0` included)
//!   reproduces the standard trainer's `pack_into` bit for bit;
//! - the proposed trainer packs Ŵᵀ straight from f16 sign bits
//!   (`pack_f16_t_into`, +1 unless strictly negative) — identical
//!   sign semantics, and pack-then-transpose ≡ direct transposed
//!   pack (pinned by `pack_f16_t_matches_pack_then_transpose`);
//! - β is carried as exact f32, matching both trainers' BN input.

use anyhow::{bail, Result};

use crate::bitops::BitMatrix;
use crate::naive::{LayerPlan, Plan};

/// One matmul layer's packed inference weights.
pub struct LayerWeights {
    /// Packed Ŵ (k×n): unpacked to ±1 f32 for first/naive-tier
    /// layers (the trainers' `signed_w_into` / `store_sign_into`).
    pub w: BitMatrix,
    /// Packed Ŵᵀ (n×k): the XNOR-GEMM operand (and the pad-correction
    /// input on the standard engine's fused conv path).
    pub wt: BitMatrix,
    /// BN shift β, exact f32.
    pub beta: Vec<f32>,
}

/// Immutable packed-weight snapshot (see module docs).  Build with
/// [`WeightSnapshot::pack`], share behind an `Arc`.
pub struct WeightSnapshot {
    version: u64,
    layers: Vec<LayerWeights>,
}

impl WeightSnapshot {
    /// Pack a snapshot from a trainer's `weights_snapshot()` image:
    /// interleaved `[w0, beta0, w1, beta1, ...]` f32 vectors, one
    /// (w, β) pair per matmul layer of `plan`.  This is the *only*
    /// copy a publish performs; the result is immutable.
    pub fn pack(plan: &Plan, weights: &[Vec<f32>], version: u64) -> Result<WeightSnapshot> {
        let wls: Vec<&LayerPlan> = plan.layers.iter().filter(|l| l.weight_len() > 0).collect();
        if weights.len() != wls.len() * 2 {
            bail!(
                "snapshot image has {} vectors, plan '{}' needs {} (w, beta per matmul layer)",
                weights.len(),
                plan.name,
                wls.len() * 2
            );
        }
        let mut layers = Vec::with_capacity(wls.len());
        for (wi, l) in wls.iter().enumerate() {
            let (k, n) = (l.fan_in(), l.channels());
            let wv = &weights[2 * wi];
            let bv = &weights[2 * wi + 1];
            if wv.len() != k * n {
                bail!("layer {wi}: weight image {} elems, want {k}x{n}", wv.len());
            }
            if bv.len() != n {
                bail!("layer {wi}: beta image {} elems, want {n}", bv.len());
            }
            let w = BitMatrix::pack(k, n, wv);
            let wt = w.transpose();
            layers.push(LayerWeights { w, wt, beta: bv.clone() });
        }
        Ok(WeightSnapshot { version, layers })
    }

    /// Monotone publish counter (set by the publisher; lets tests and
    /// metrics tell which weights served a response).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn layer(&self, wi: usize) -> &LayerWeights {
        &self.layers[wi]
    }

    pub fn layers(&self) -> usize {
        self.layers.len()
    }

    /// True when this snapshot's shapes fit `plan` (layer count +
    /// per-layer k×n) — the install-time compatibility gate.
    pub fn matches(&self, plan: &Plan) -> bool {
        let wls: Vec<&LayerPlan> = plan.layers.iter().filter(|l| l.weight_len() > 0).collect();
        wls.len() == self.layers.len()
            && wls.iter().zip(&self.layers).all(|(l, s)| {
                s.w.rows == l.fan_in()
                    && s.w.cols == l.channels()
                    && s.beta.len() == l.channels()
            })
    }

    /// Resident bytes (packed w + wt words, β f32) — the serve-side
    /// analogue of the trainers' packed-weight-cache term.
    pub fn heap_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.w.heap_bytes() + l.wt.heap_bytes() + l.beta.len() * 4)
            .sum()
    }

    /// FNV-1a digest over every packed weight word and β bit pattern.
    /// Two snapshots digest equal iff they would serve bit-identical
    /// logits — the cheap identity the multi-tenant isolation tests
    /// and the CLI demo print instead of whole weight images.
    pub fn bit_digest(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
        };
        for l in &self.layers {
            for &w in &l.w.data {
                mix(w);
            }
            for &w in &l.wt.data {
                mix(w);
            }
            for &b in &l.beta {
                mix(b.to_bits() as u64);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};
    use crate::naive::{build_engine, Accel, StepEngine};

    #[test]
    fn pack_roundtrips_trainer_snapshot() {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        for algo in ["standard", "proposed"] {
            let eng = build_engine(algo, &graph, 4, "adam", Accel::Blocked, 9).unwrap();
            let img = eng.weights_snapshot();
            let snap = WeightSnapshot::pack(&plan, &img, 1).unwrap();
            assert_eq!(snap.layers(), plan.weight_layers());
            assert!(snap.matches(&plan), "{algo}");
            assert!(snap.heap_bytes() > 0);
            assert_eq!(snap.version(), 1);
            // wt really is the word transpose of w, and signs mirror
            // the f32 image (v >= 0 ⇒ +1)
            for (wi, l) in snap.layers.iter().enumerate() {
                assert_eq!(l.wt, l.w.transpose(), "{algo} layer {wi}");
                let img_w = &img[2 * wi];
                assert_eq!(
                    l.w.get(0, 0),
                    if img_w[0] >= 0.0 { 1.0 } else { -1.0 },
                    "{algo} layer {wi}"
                );
            }
        }
    }

    #[test]
    fn bit_digest_is_a_weight_identity() {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let eng = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 9).unwrap();
        let img = eng.weights_snapshot();
        let a = WeightSnapshot::pack(&plan, &img, 1).unwrap();
        let b = WeightSnapshot::pack(&plan, &img, 2).unwrap();
        // same bits, different version: digest ignores the version
        assert_eq!(a.bit_digest(), b.bit_digest());
        let other = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 10).unwrap();
        let c = WeightSnapshot::pack(&plan, &other.weights_snapshot(), 1).unwrap();
        assert_ne!(a.bit_digest(), c.bit_digest(), "different seeds, same digest");
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let eng = build_engine("standard", &graph, 4, "adam", Accel::Blocked, 9).unwrap();
        let mut img = eng.weights_snapshot();
        assert!(WeightSnapshot::pack(&plan, &img[..2], 0).is_err(), "layer count");
        img[0].pop();
        assert!(WeightSnapshot::pack(&plan, &img, 0).is_err(), "weight shape");

        // matches() catches a snapshot from a different model
        let other = lower(&get("cnv_mini").unwrap()).unwrap();
        let other_plan = Plan::from_graph(&other).unwrap();
        let eng2 = build_engine("standard", &other, 4, "adam", Accel::Blocked, 9).unwrap();
        let snap2 = WeightSnapshot::pack(&other_plan, &eng2.weights_snapshot(), 0).unwrap();
        assert!(!snap2.matches(&plan));
    }
}
