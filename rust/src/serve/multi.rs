//! Multi-tenant schedule runtime: N models' compiled schedules
//! co-scheduled on one worker pool.
//!
//! [`MultiModelServer`] hosts a fleet of [`Tenant`]s — each a
//! compiled train and/or serve `StepSchedule` with its own slot arena
//! and `WeightSnapshot` chain — and executes them on `lanes` driver
//! threads.  The kernels inside every quantum still run on the
//! **process-global** `bitops::Pool` workers, so lanes never
//! oversubscribe cores: a lane driving one tenant's serial
//! pack/BN/optimizer region leaves the pool free for another lane's
//! GEMM bands, which is exactly where the co-scheduling throughput
//! win over time-sliced serial execution comes from
//! (`benches/perf_multi.rs`, CI-gated ≥1.5×).
//!
//! ## Work-conserving interleaver
//!
//! Per tenant there is a run queue pair (infer requests, train
//! requests) plus a parked published snapshot.  Lanes pick the next
//! runnable tenant **round-robin** from a shared cursor, check the
//! tenant out of the shared state, and run one *quantum*:
//!
//! - **Infer** — drain up to `max_batch` queued requests, gather,
//!   one forward, scatter (the dynamic-batching policy of
//!   [`super::BatchServer`], greedy rather than SLO-waiting: with
//!   multiple tenants there is always other work, so a lane never
//!   sleeps on tenant A while tenant B has requests).
//! - **Train** — one training step (plus the tenant's periodic
//!   auto-publish into its own serve engine).
//! - **Install** — a parked snapshot with no queued work.
//!
//! Quantum boundaries are the **preemption points**: a parked
//! snapshot is installed before the quantum (every batch sees exactly
//! one weight version — the [`super::Batcher`] discipline), and at
//! check-in the tenant's arenas must be quiescent
//! ([`Tenant::is_idle`]) so a tenant can migrate between lanes
//! without leaking a checked-out slot.  Tenants with both queues
//! nonempty alternate train/infer quanta (`prefer_train` flips at
//! each pick), so co-resident serving is never starved by a hot
//! training loop or vice versa.
//!
//! ## Zero-allocation steady state
//!
//! The request protocol is the raw-pointer scheme of
//! [`super::batcher`] (clients block until their done flag is set, so
//! the pointees outlive every server access; output writes and flag
//! stores happen under the shared mutex, which provides the
//! happens-before edge).  Queues are pre-sized and capacity-guarded,
//! lanes gather/scatter through the tenant's pre-sized staging
//! buffers, and engines execute their compiled schedules — after
//! warmup, a steady-state quantum performs zero heap allocations
//! (hard-asserted in rust/tests/memtrack_multi.rs; auto-publish packs
//! a fresh snapshot and is the one deliberate exception).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use super::snapshot::WeightSnapshot;
use super::tenant::{Tenant, TenantSpec};
use crate::naive::Plan;

/// One queued inference request (pointers into the blocked client's
/// buffers — see module docs).
struct InferReq {
    x: *const f32,
    out: *mut f32,
    done: *const AtomicBool,
}

/// One queued training step: a whole pre-staged batch.
struct TrainReq {
    x: *const f32,
    y: *const usize,
    lr: f32,
    result: *mut (f32, f32),
    done: *const AtomicBool,
}

// The client blocks until `done` is set, so the pointees outlive
// every server access (same argument as serve::batcher::Req).
unsafe impl Send for InferReq {}
unsafe impl Send for TrainReq {}

/// Immutable per-tenant facts, readable without the state lock
/// (submit-time validation).
struct TenantMeta {
    name: String,
    input_elems: usize,
    classes: usize,
    train_batch: usize,
    max_batch: usize,
    queue_cap: usize,
    has_train: bool,
    has_serve: bool,
    plan: Plan,
}

/// Mutable per-tenant scheduling state.
struct TenantSlot {
    /// `None` while a lane has the tenant checked out.
    tenant: Option<Box<Tenant>>,
    infer_q: VecDeque<InferReq>,
    train_q: VecDeque<TrainReq>,
    /// Parked by `publish`, installed at the next quantum boundary.
    pending_snap: Option<Arc<WeightSnapshot>>,
    /// Alternation bit for TrainServe tenants with both queues
    /// nonempty.
    prefer_train: bool,
    served: u64,
    steps: u64,
}

struct MultiState {
    slots: Vec<TenantSlot>,
    /// Round-robin cursor: the next pick scans from here.
    rr: usize,
    shutdown: bool,
    failed: bool,
    /// Quanta currently executing outside the lock.
    inflight: usize,
}

struct MultiShared {
    m: Mutex<MultiState>,
    /// Runnable work appeared (lanes wake to pick).
    work: Condvar,
    /// A quantum completed (clients re-check their done flags).
    completed: Condvar,
    /// Queue space freed (back-pressured clients retry).
    space: Condvar,
    meta: Vec<TenantMeta>,
    lanes: usize,
}

/// What a lane checked out for one quantum.
enum Quantum {
    /// Requests already drained into the lane-local batch vec.
    Infer,
    Train(TrainReq),
    /// A parked snapshot with no queued work.
    Install,
}

/// Client + publisher handle to a running [`MultiModelServer`]
/// (cheap to clone; one per client thread).
#[derive(Clone)]
pub struct MultiClient {
    sh: Arc<MultiShared>,
}

impl MultiClient {
    fn meta(&self, tid: usize) -> Result<&TenantMeta> {
        self.sh
            .meta
            .get(tid)
            .ok_or_else(|| anyhow!("no tenant {tid} (fleet has {})", self.sh.meta.len()))
    }

    /// Submit one sample to tenant `tid` and block until its logits
    /// arrive.  Allocation-free.
    pub fn infer_one(&self, tid: usize, x: &[f32], out: &mut [f32]) -> Result<()> {
        let meta = self.meta(tid)?;
        if !meta.has_serve {
            bail!("tenant '{}' has no serving role", meta.name);
        }
        if x.len() != meta.input_elems {
            bail!("input is {} elems, want {}", x.len(), meta.input_elems);
        }
        if out.len() != meta.classes {
            bail!("output is {} elems, want {}", out.len(), meta.classes);
        }
        let done = AtomicBool::new(false);
        let req = InferReq { x: x.as_ptr(), out: out.as_mut_ptr(), done: &done };
        let mut st = self.sh.m.lock().unwrap();
        while st.slots[tid].infer_q.len() >= meta.queue_cap && !st.shutdown {
            st = self.sh.space.wait(st).unwrap();
        }
        if st.shutdown {
            bail!("multi server is shut down");
        }
        st.slots[tid].infer_q.push_back(req);
        self.sh.work.notify_all();
        // once enqueued we *must* wait (the server owns our pointers
        // until it sets done)
        while !done.load(Ordering::Relaxed) {
            st = self.sh.completed.wait(st).unwrap();
        }
        if st.failed {
            bail!("multi server failed");
        }
        Ok(())
    }

    /// Submit one training step (a whole pre-staged batch) to tenant
    /// `tid` and block for its (loss, accuracy).
    pub fn train_step(&self, tid: usize, x: &[f32], y: &[usize], lr: f32) -> Result<(f32, f32)> {
        let meta = self.meta(tid)?;
        if !meta.has_train {
            bail!("tenant '{}' has no training role", meta.name);
        }
        if x.len() != meta.train_batch * meta.input_elems || y.len() != meta.train_batch {
            bail!("bad batch shapes for tenant '{}'", meta.name);
        }
        let mut result = (0.0f32, 0.0f32);
        let done = AtomicBool::new(false);
        let req = TrainReq {
            x: x.as_ptr(),
            y: y.as_ptr(),
            lr,
            result: &mut result,
            done: &done,
        };
        let mut st = self.sh.m.lock().unwrap();
        while st.slots[tid].train_q.len() >= meta.queue_cap && !st.shutdown {
            st = self.sh.space.wait(st).unwrap();
        }
        if st.shutdown {
            bail!("multi server is shut down");
        }
        st.slots[tid].train_q.push_back(req);
        self.sh.work.notify_all();
        while !done.load(Ordering::Relaxed) {
            st = self.sh.completed.wait(st).unwrap();
        }
        if st.failed {
            bail!("multi server failed");
        }
        Ok(result)
    }

    /// Park a snapshot for tenant `tid`, installed at its next
    /// quantum boundary (copy-on-publish).  Shapes are validated
    /// here, so the lane-side install cannot fail.
    pub fn publish(&self, tid: usize, snap: Arc<WeightSnapshot>) -> Result<()> {
        let meta = self.meta(tid)?;
        if !meta.has_serve {
            bail!("tenant '{}' has no serving role", meta.name);
        }
        if !snap.matches(&meta.plan) {
            bail!("snapshot does not match tenant '{}'", meta.name);
        }
        let mut st = self.sh.m.lock().unwrap();
        st.slots[tid].pending_snap = Some(snap);
        self.sh.work.notify_all();
        Ok(())
    }

    /// Stop accepting work; lanes drain what is queued and exit.
    pub fn shutdown(&self) {
        self.sh.m.lock().unwrap().shutdown = true;
        self.sh.work.notify_all();
        self.sh.space.notify_all();
    }

    /// Requests served by tenant `tid` so far.
    pub fn served(&self, tid: usize) -> u64 {
        self.sh.m.lock().unwrap().slots[tid].served
    }

    /// Training steps executed by tenant `tid` so far.
    pub fn steps(&self, tid: usize) -> u64 {
        self.sh.m.lock().unwrap().slots[tid].steps
    }
}

/// The co-scheduling runtime (see module docs).  Build with
/// [`MultiModelServer::new`], call [`MultiModelServer::run`].
pub struct MultiModelServer {
    sh: Arc<MultiShared>,
}

impl MultiModelServer {
    /// Build the fleet: one [`Tenant`] per spec, `lanes` driver
    /// threads (1 = time-sliced serial execution — the bench
    /// baseline).
    pub fn new(specs: Vec<TenantSpec>, lanes: usize) -> Result<(MultiClient, MultiModelServer)> {
        if specs.is_empty() {
            bail!("multi server needs at least one tenant");
        }
        if lanes == 0 {
            bail!("multi server needs at least one lane");
        }
        let mut meta = Vec::with_capacity(specs.len());
        let mut slots = Vec::with_capacity(specs.len());
        for spec in specs {
            let tenant = Tenant::new(spec)?;
            let spec = tenant.spec();
            meta.push(TenantMeta {
                name: spec.name.clone(),
                input_elems: tenant.graph().input_elems,
                classes: tenant.graph().classes,
                train_batch: spec.batch,
                max_batch: spec.max_batch,
                queue_cap: spec.queue_cap,
                has_train: spec.role.trains(),
                has_serve: spec.role.serves(),
                plan: tenant.plan().clone(),
            });
            let cap = spec.queue_cap;
            slots.push(TenantSlot {
                tenant: Some(Box::new(tenant)),
                infer_q: VecDeque::with_capacity(cap),
                train_q: VecDeque::with_capacity(cap),
                pending_snap: None,
                prefer_train: false,
                served: 0,
                steps: 0,
            });
        }
        let sh = Arc::new(MultiShared {
            m: Mutex::new(MultiState {
                slots,
                rr: 0,
                shutdown: false,
                failed: false,
                inflight: 0,
            }),
            work: Condvar::new(),
            completed: Condvar::new(),
            space: Condvar::new(),
            meta,
            lanes,
        });
        Ok((MultiClient { sh: Arc::clone(&sh) }, MultiModelServer { sh }))
    }

    /// Planned steady-state bytes of the whole fleet: the exact sum
    /// of per-tenant schedule folds.
    pub fn fleet_envelope(&self) -> Result<crate::memmodel::FleetEnvelope> {
        let st = self.sh.m.lock().unwrap();
        let loads: Vec<crate::memmodel::TenantLoad> = st
            .slots
            .iter()
            .map(|s| s.tenant.as_ref().expect("pre-run").load())
            .collect();
        crate::memmodel::fleet_envelope(&loads)
    }

    /// Measured steady-state bytes of the whole fleet (pre-run: every
    /// tenant checked in).
    pub fn steady_state_bytes(&self) -> usize {
        let st = self.sh.m.lock().unwrap();
        st.slots
            .iter()
            .map(|s| s.tenant.as_ref().expect("pre-run").steady_state_bytes())
            .sum()
    }

    /// Serve until shutdown: this thread becomes lane 0, `lanes - 1`
    /// more are spawned.  Returns the tenants (trained weights,
    /// installed snapshots, counters) once every queue is drained.
    pub fn run(self) -> Result<Vec<Tenant>> {
        let sh = self.sh;
        let mut handles = Vec::new();
        for l in 1..sh.lanes {
            let sh2 = Arc::clone(&sh);
            handles.push(std::thread::spawn(move || lane(&sh2, l)));
        }
        let mut first_err = lane(&sh, 0).err();
        for h in handles {
            if let Err(e) = h.join().expect("lane panicked") {
                first_err.get_or_insert(e);
            }
        }
        let mut st = sh.m.lock().unwrap();
        debug_assert_eq!(st.inflight, 0, "lanes exited with a quantum in flight");
        // failure path: release clients whose requests were never
        // drained (no outputs written; they observe `failed`)
        for slot in &mut st.slots {
            for r in slot.infer_q.drain(..) {
                unsafe { (*r.done).store(true, Ordering::Relaxed) };
            }
            for r in slot.train_q.drain(..) {
                unsafe { (*r.done).store(true, Ordering::Relaxed) };
            }
        }
        sh.completed.notify_all();
        let mut tenants = Vec::with_capacity(st.slots.len());
        for slot in &mut st.slots {
            let mut t = *slot.tenant.take().expect("tenant checked out at exit");
            // a snapshot published after the tenant's last quantum is
            // still parked — install it so the returned tenant serves
            // the newest weights (the BatchServer shutdown fix,
            // applied fleet-wide)
            if first_err.is_none() {
                if let Some(s) = slot.pending_snap.take() {
                    t.install_pending(s)?;
                }
            }
            tenants.push(t);
        }
        drop(st);
        match first_err {
            Some(e) => Err(e),
            None => Ok(tenants),
        }
    }
}

/// One checked-out quantum, ready to execute outside the lock.
struct Checkout {
    tid: usize,
    tenant: Box<Tenant>,
    snap: Option<Arc<WeightSnapshot>>,
    quantum: Quantum,
}

/// Scan for the next runnable tenant from the round-robin cursor and
/// check it out.  `batch` receives the drained infer requests.
fn pick(
    st: &mut MultiState,
    meta: &[TenantMeta],
    batch: &mut Vec<InferReq>,
) -> Option<Checkout> {
    let n = st.slots.len();
    for i in 0..n {
        let tid = (st.rr + i) % n;
        let m = &meta[tid];
        let slot = &mut st.slots[tid];
        if slot.tenant.is_none() {
            continue; // checked out by another lane
        }
        let can_infer = m.has_serve && !slot.infer_q.is_empty();
        let can_train = m.has_train && !slot.train_q.is_empty();
        let can_install = m.has_serve && slot.pending_snap.is_some();
        if !can_infer && !can_train && !can_install {
            continue;
        }
        let quantum = if can_train && (!can_infer || slot.prefer_train) {
            slot.prefer_train = false;
            Quantum::Train(slot.train_q.pop_front().unwrap())
        } else if can_infer {
            slot.prefer_train = true;
            let take = slot.infer_q.len().min(m.max_batch);
            for _ in 0..take {
                batch.push(slot.infer_q.pop_front().unwrap());
            }
            Quantum::Infer
        } else {
            Quantum::Install
        };
        let co = Checkout {
            tid,
            tenant: slot.tenant.take().unwrap(),
            snap: slot.pending_snap.take(),
            quantum,
        };
        st.rr = (tid + 1) % n;
        st.inflight += 1;
        return Some(co);
    }
    None
}

/// One driver thread: pick → install parked snapshot → run the
/// quantum → check the tenant back in at the boundary.
fn lane(sh: &Arc<MultiShared>, _lane_id: usize) -> Result<()> {
    let max_mb = sh.meta.iter().map(|m| m.max_batch).max().unwrap_or(1);
    let mut batch: Vec<InferReq> = Vec::with_capacity(max_mb);
    loop {
        let co = {
            let mut st = sh.m.lock().unwrap();
            loop {
                if st.failed {
                    return Ok(()); // the failing lane reported
                }
                if let Some(co) = pick(&mut st, &sh.meta, &mut batch) {
                    // the condvar is shared across tenants, so wake
                    // every back-pressured client to re-check its own
                    // queue
                    sh.space.notify_all();
                    break co;
                }
                if st.shutdown && st.inflight == 0 {
                    return Ok(()); // drained fleet-wide
                }
                st = sh.work.wait(st).unwrap();
            }
        };
        let tid = co.tid;
        let mut tenant = co.tenant;
        let meta = &sh.meta[tid];
        let r = run_quantum(&mut tenant, meta, co.snap, &co.quantum, &batch);
        // check-in: outputs, done flags and counters land under the
        // mutex (the happens-before edge for the raw pointers), then
        // the tenant returns to its slot for the next lane
        let mut st = sh.m.lock().unwrap();
        match r {
            Ok(()) => {
                debug_assert!(tenant.is_idle(), "tenant '{}' non-idle at check-in", meta.name);
                let cl = meta.classes;
                match &co.quantum {
                    Quantum::Infer => {
                        for (i, req) in batch.iter().enumerate() {
                            let dst = unsafe { std::slice::from_raw_parts_mut(req.out, cl) };
                            dst.copy_from_slice(&tenant.batch_logits[i * cl..(i + 1) * cl]);
                            unsafe { (*req.done).store(true, Ordering::Relaxed) };
                        }
                        st.slots[tid].served += batch.len() as u64;
                        batch.clear();
                    }
                    Quantum::Train(req) => {
                        unsafe { (*req.done).store(true, Ordering::Relaxed) };
                        st.slots[tid].steps += 1;
                    }
                    Quantum::Install => {}
                }
                st.slots[tid].tenant = Some(tenant);
                st.inflight -= 1;
                sh.completed.notify_all();
                sh.work.notify_all();
            }
            Err(e) => {
                // release this quantum's clients (no outputs written —
                // they observe `failed`), check the tenant back in,
                // and take the whole fleet down
                match &co.quantum {
                    Quantum::Infer => {
                        for req in batch.drain(..) {
                            unsafe { (*req.done).store(true, Ordering::Relaxed) };
                        }
                    }
                    Quantum::Train(req) => {
                        unsafe { (*req.done).store(true, Ordering::Relaxed) };
                    }
                    Quantum::Install => {}
                }
                st.slots[tid].tenant = Some(tenant);
                st.inflight -= 1;
                st.failed = true;
                st.shutdown = true;
                sh.completed.notify_all();
                sh.work.notify_all();
                sh.space.notify_all();
                return Err(e);
            }
        }
    }
}

/// Execute one quantum outside the lock.  The training result is
/// written through the request pointer here (the client cannot
/// observe it until its done flag is set under the mutex).
fn run_quantum(
    tenant: &mut Tenant,
    meta: &TenantMeta,
    snap: Option<Arc<WeightSnapshot>>,
    quantum: &Quantum,
    batch: &[InferReq],
) -> Result<()> {
    if let Some(s) = snap {
        tenant.install_pending(s)?;
    }
    match quantum {
        Quantum::Infer => {
            let ie = meta.input_elems;
            for (i, req) in batch.iter().enumerate() {
                let src = unsafe { std::slice::from_raw_parts(req.x, ie) };
                tenant.batch_x[i * ie..(i + 1) * ie].copy_from_slice(src);
            }
            tenant.run_infer(batch.len())
        }
        Quantum::Train(req) => {
            let x =
                unsafe { std::slice::from_raw_parts(req.x, meta.train_batch * meta.input_elems) };
            let y = unsafe { std::slice::from_raw_parts(req.y, meta.train_batch) };
            let out = tenant.run_train(x, y, req.lr)?;
            unsafe { *req.result = out };
            tenant.maybe_autopublish()?;
            Ok(())
        }
        Quantum::Install => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::naive::{build_engine, Accel, StepEngine};
    use crate::serve::engine::{InferAlgo, PackedInferEngine};
    use crate::serve::tenant::TenantRole;
    use crate::util::rng::Pcg32;

    fn serve_spec(name: &str, model: &str, seed: u64) -> TenantSpec {
        let mut s = TenantSpec::new(name, model, TenantRole::Serve);
        s.seed = seed;
        s.max_batch = 4;
        s
    }

    #[test]
    fn cosched_serve_tenants_match_solo_engines() {
        // two models, two lanes, concurrent clients: every tenant's
        // logits must be bit-identical to a solo engine on the same
        // snapshot (sequential batch-1 submissions keep the BN batch
        // composition deterministic)
        let specs = vec![serve_spec("a", "mlp_mini", 5), serve_spec("b", "cnv_mini", 6)];
        // a serve-only tenant packs its initial snapshot from a
        // throwaway trainer seeded with spec.seed; weight init depends
        // only on the seed and the shapes, so the same pack here is
        // bit-identical to what each tenant serves
        let snaps: Vec<Arc<WeightSnapshot>> = [("mlp_mini", 5u64), ("cnv_mini", 6u64)]
            .iter()
            .map(|(model, seed)| {
                let graph = crate::models::lower(&crate::models::get(model).unwrap()).unwrap();
                let plan = Plan::from_graph(&graph).unwrap();
                let t = build_engine("proposed", &graph, 1, "adam", Accel::Blocked, *seed)
                    .unwrap();
                Arc::new(WeightSnapshot::pack(&plan, &t.weights_snapshot(), 0).unwrap())
            })
            .collect();
        let (client, server) = MultiModelServer::new(specs, 2).unwrap();
        let h = std::thread::spawn(move || server.run());
        let mut workers = Vec::new();
        for (tid, model) in [(0usize, "mlp_mini"), (1usize, "cnv_mini")] {
            let c = client.clone();
            let snap = Arc::clone(&snaps[tid]);
            workers.push(std::thread::spawn(move || {
                let graph = crate::models::lower(&crate::models::get(model).unwrap()).unwrap();
                let mut solo =
                    PackedInferEngine::new(&graph, InferAlgo::Proposed, Accel::Blocked, 4, snap)
                        .unwrap();
                let ie = graph.input_elems;
                let cl = graph.classes;
                let mut rng = Pcg32::new(40 + tid as u64);
                let mut got = vec![0.0f32; cl];
                let mut want = vec![0.0f32; cl];
                for _ in 0..16 {
                    let x = rng.normal_vec(ie);
                    c.infer_one(tid, &x, &mut got).unwrap();
                    solo.infer_into(&x, 1, &mut want).unwrap();
                    assert_eq!(got, want, "tenant {tid} diverged from solo");
                }
            }));
        }
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(client.served(0), 16);
        assert_eq!(client.served(1), 16);
        client.shutdown();
        let tenants = h.join().unwrap().unwrap();
        assert!(tenants.iter().all(|t| t.is_idle()));
    }

    #[test]
    fn train_through_the_fleet_matches_solo_training() {
        let graph = crate::models::lower(&crate::models::get("mlp_mini").unwrap()).unwrap();
        let mut spec = TenantSpec::new("t", "mlp_mini", TenantRole::Train);
        spec.batch = 8;
        spec.seed = 11;
        let (client, server) = MultiModelServer::new(vec![spec], 2).unwrap();
        let h = std::thread::spawn(move || server.run());
        let mut solo = build_engine("proposed", &graph, 8, "adam", Accel::Blocked, 11).unwrap();
        let ie = graph.input_elems;
        let cl = graph.classes;
        let mut rng = Pcg32::new(21);
        for _ in 0..4 {
            let x = rng.normal_vec(ie * 8);
            let y: Vec<usize> = (0..8).map(|i| (i * 3) % cl).collect();
            let got = client.train_step(0, &x, &y, 0.01).unwrap();
            let want = solo.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(got, want, "loss/acc diverged");
        }
        assert_eq!(client.steps(0), 4);
        client.shutdown();
        let tenants = h.join().unwrap().unwrap();
        assert_eq!(
            tenants[0].train_engine().unwrap().weights_snapshot(),
            solo.weights_snapshot(),
            "weights diverged from the solo run"
        );
    }

    #[test]
    fn publish_installs_at_quantum_boundary_and_survives_shutdown() {
        let graph = crate::models::lower(&crate::models::get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let other = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 77).unwrap();
        let snap1 =
            Arc::new(WeightSnapshot::pack(&plan, &other.weights_snapshot(), 1).unwrap());

        let (client, server) = MultiModelServer::new(vec![serve_spec("a", "mlp_mini", 5)], 1)
            .unwrap();
        let h = std::thread::spawn(move || server.run());
        let mut rng = Pcg32::new(9);
        let x = rng.normal_vec(graph.input_elems);
        let mut got = vec![0.0f32; graph.classes];
        client.infer_one(0, &x, &mut got).unwrap();
        client.publish(0, Arc::clone(&snap1)).unwrap();
        client.infer_one(0, &x, &mut got).unwrap();
        let snap1c = Arc::clone(&snap1);
        let mut solo =
            PackedInferEngine::new(&graph, InferAlgo::Proposed, Accel::Blocked, 4, snap1c)
                .unwrap();
        let mut want = vec![0.0f32; graph.classes];
        solo.infer_into(&x, 1, &mut want).unwrap();
        assert_eq!(got, want, "published snapshot applies at the next quantum");

        // a publish parked after the last quantum must survive the
        // drain (the BatchServer shutdown fix, fleet-wide)
        let other2 = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 78).unwrap();
        let snap2 =
            Arc::new(WeightSnapshot::pack(&plan, &other2.weights_snapshot(), 2).unwrap());
        client.publish(0, Arc::clone(&snap2)).unwrap();
        client.shutdown();
        let tenants = h.join().unwrap().unwrap();
        let served = tenants[0].serve_engine().unwrap().snapshot();
        assert_eq!(served.version(), 2);
        assert_eq!(served.bit_digest(), snap2.bit_digest());
        assert!(client.infer_one(0, &x, &mut got).is_err(), "post-shutdown submit");
    }

    #[test]
    fn trainserve_autopublish_serves_fresh_weights() {
        let mut spec = TenantSpec::new("ts", "mlp_mini", TenantRole::TrainServe);
        spec.batch = 8;
        spec.max_batch = 2;
        spec.publish_every = 2;
        spec.seed = 13;
        let (client, server) = MultiModelServer::new(vec![spec], 2).unwrap();
        let graph = crate::models::lower(&crate::models::get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let h = std::thread::spawn(move || server.run());
        // solo mirror: same engine, same data, repacking every 2 steps
        let mut solo = build_engine("proposed", &graph, 8, "adam", Accel::Blocked, 13).unwrap();
        let ie = graph.input_elems;
        let cl = graph.classes;
        let mut rng = Pcg32::new(31);
        for step in 1..=4u64 {
            let x = rng.normal_vec(ie * 8);
            let y: Vec<usize> = (0..8).map(|i| (i + step as usize) % cl).collect();
            client.train_step(0, &x, &y, 0.01).unwrap();
            solo.train_step(&x, &y, 0.01).unwrap();
        }
        // after 4 steps the tenant has auto-published version 2; a
        // served request must use exactly those weights
        let probe = rng.normal_vec(ie);
        let mut got = vec![0.0f32; cl];
        client.infer_one(0, &probe, &mut got).unwrap();
        let mirror = Arc::new(WeightSnapshot::pack(&plan, &solo.weights_snapshot(), 2).unwrap());
        let mut reference =
            PackedInferEngine::new(&graph, InferAlgo::Proposed, Accel::Blocked, 2, mirror).unwrap();
        let mut want = vec![0.0f32; cl];
        reference.infer_into(&probe, 1, &mut want).unwrap();
        assert_eq!(got, want, "served logits must come from the auto-published weights");
        client.shutdown();
        let tenants = h.join().unwrap().unwrap();
        assert_eq!(tenants[0].published(), 2);
        assert_eq!(tenants[0].steps(), 4);
        assert_eq!(tenants[0].served(), 1);
    }

    #[test]
    fn fleet_envelope_is_exact_pre_run() {
        // serve-only fleet: the envelope is exact even before any
        // quantum runs (train tenants need warmup steps for the
        // packed-weight cache term — pinned in tests/multi_tenant.rs)
        let specs = vec![serve_spec("a", "mlp_mini", 5), serve_spec("b", "cnv_mini", 6)];
        let (client, server) = MultiModelServer::new(specs, 1).unwrap();
        let planned = server.fleet_envelope().unwrap().total_bytes() as usize;
        assert_eq!(planned, server.steady_state_bytes());
        client.shutdown();
        server.run().unwrap();
    }

    #[test]
    fn bad_submissions_are_rejected() {
        let (client, server) = MultiModelServer::new(vec![serve_spec("a", "mlp_mini", 5)], 1)
            .unwrap();
        let mut out = vec![0.0f32; 16];
        assert!(client.infer_one(7, &[0.0; 4], &mut out).is_err(), "no such tenant");
        assert!(client.infer_one(0, &[0.0; 3], &mut out).is_err(), "bad input len");
        assert!(client.train_step(0, &[0.0; 4], &[0], 0.1).is_err(), "no train role");
        client.shutdown();
        server.run().unwrap();
    }
}
