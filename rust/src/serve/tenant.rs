//! One co-scheduled model on the multi-tenant runtime.
//!
//! A [`Tenant`] bundles everything one model needs to run on a
//! [`super::MultiModelServer`] lane: its lowered graph + plan, a
//! compiled **train** engine and/or a compiled **serve** engine (each
//! executing its own slot-colored `StepSchedule` through its own
//! `StepArena`), the per-tenant gather/scatter staging buffers, and
//! the tenant's `WeightSnapshot` chain.
//!
//! Tenants are *checked out* of the shared state by whichever lane
//! thread runs their next quantum and checked back in at the batch
//! boundary — so everything here is owned data (`Send`), and the
//! quiescence invariant ([`Tenant::is_idle`]) is asserted at every
//! hand-off: a tenant that crossed lanes with an arena buffer still
//! checked out would leak that slot into the next lane's pass.
//!
//! Live train-and-serve is the [`TenantRole::TrainServe`] role: after
//! every `publish_every` training steps the tenant packs its latent
//! weights into a fresh snapshot (version = publish count) and
//! installs it into its own serve engine — the same copy-on-publish
//! discipline as [`super::Batcher::publish`], executed at a lane
//! batch boundary so no in-flight request ever sees mixed weights.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::engine::{InferAlgo, PackedInferEngine};
use super::snapshot::WeightSnapshot;
use crate::memmodel::{self, Optimizer};
use crate::models::{get, lower, Graph};
use crate::naive::{build_engine_micro_send, Accel, Plan, StepEngine};

/// Which schedules a tenant runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantRole {
    /// Training steps only.
    Train,
    /// Inference requests only.
    Serve,
    /// Both, with periodic copy-on-publish from train to serve.
    TrainServe,
}

impl TenantRole {
    pub fn trains(&self) -> bool {
        matches!(self, TenantRole::Train | TenantRole::TrainServe)
    }

    pub fn serves(&self) -> bool {
        matches!(self, TenantRole::Serve | TenantRole::TrainServe)
    }
}

/// Declarative tenant configuration (everything [`Tenant::new`] needs
/// to build the engines).
#[derive(Clone)]
pub struct TenantSpec {
    /// Display name (defaults to the model name).
    pub name: String,
    /// Zoo model.
    pub model: String,
    /// "standard" | "proposed".
    pub algo: String,
    pub accel: Accel,
    pub optimizer: String,
    pub seed: u64,
    pub role: TenantRole,
    /// Training batch (roles that train).
    pub batch: usize,
    /// Training microbatch (0 = whole batch).
    pub microbatch: usize,
    /// Serving batch cap (roles that serve).
    pub max_batch: usize,
    /// `TrainServe`: auto-publish into the serve engine every N
    /// training steps (0 = only explicit publishes).
    pub publish_every: usize,
    /// Per-tenant request queue capacity.
    pub queue_cap: usize,
    /// Initial serving snapshot; `None` packs one from the tenant's
    /// freshly seeded weights.
    pub init: Option<Arc<WeightSnapshot>>,
}

impl TenantSpec {
    pub fn new(name: &str, model: &str, role: TenantRole) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            model: model.to_string(),
            algo: "proposed".to_string(),
            accel: Accel::Blocked,
            optimizer: "adam".to_string(),
            seed: 42,
            role,
            batch: 16,
            microbatch: 0,
            max_batch: 8,
            publish_every: 0,
            queue_cap: 32,
            init: None,
        }
    }
}

/// A built tenant: owned engines + staging, checked out by one lane
/// at a time (see module docs).
pub struct Tenant {
    spec: TenantSpec,
    graph: Graph,
    plan: Plan,
    opt: Optimizer,
    train: Option<Box<dyn StepEngine + Send>>,
    serve: Option<PackedInferEngine>,
    /// Gather staging, `max_batch × input_elems` (serving roles).
    pub(crate) batch_x: Vec<f32>,
    /// Scatter staging, `max_batch × classes` (serving roles).
    pub(crate) batch_logits: Vec<f32>,
    steps: u64,
    served: u64,
    published: u64,
}

impl Tenant {
    pub fn new(spec: TenantSpec) -> Result<Tenant> {
        let graph = lower(&get(&spec.model)?)?;
        let plan = Plan::from_graph(&graph)?;
        let opt = Optimizer::parse(&spec.optimizer)
            .ok_or_else(|| anyhow::anyhow!("unknown optimizer '{}'", spec.optimizer))?;
        if spec.role.trains() {
            if spec.batch == 0 {
                bail!("tenant '{}': training role needs a positive batch", spec.name);
            }
            let micro = if spec.microbatch == 0 { spec.batch } else { spec.microbatch };
            if spec.batch % micro != 0 {
                bail!("tenant '{}': microbatch must divide batch", spec.name);
            }
        }
        if spec.role.serves() {
            if spec.max_batch == 0 {
                bail!("tenant '{}': serving role needs a positive max_batch", spec.name);
            }
            if spec.queue_cap < spec.max_batch {
                bail!(
                    "tenant '{}': queue_cap {} below max_batch {}",
                    spec.name,
                    spec.queue_cap,
                    spec.max_batch
                );
            }
        }
        let train = if spec.role.trains() {
            Some(build_engine_micro_send(
                &spec.algo,
                &graph,
                spec.batch,
                spec.microbatch,
                &spec.optimizer,
                spec.accel,
                spec.seed,
            )?)
        } else {
            None
        };
        let (serve, published) = if spec.role.serves() {
            let snap = match (&spec.init, &train) {
                (Some(s), _) => Arc::clone(s),
                // TrainServe starts serving its own initial weights
                (None, Some(t)) => Arc::new(WeightSnapshot::pack(&plan, &t.weights_snapshot(), 0)?),
                // Serve-only without an init: a throwaway seeded
                // trainer supplies the weights (demo/bench path)
                (None, None) => {
                    let t = build_engine_micro_send(
                        &spec.algo,
                        &graph,
                        1,
                        0,
                        &spec.optimizer,
                        spec.accel,
                        spec.seed,
                    )?;
                    Arc::new(WeightSnapshot::pack(&plan, &t.weights_snapshot(), 0)?)
                }
            };
            let version = snap.version();
            let algo = InferAlgo::parse(&spec.algo)?;
            let mut eng =
                PackedInferEngine::new(&graph, algo, spec.accel, spec.max_batch, snap)?;
            eng.warmup()?;
            (Some(eng), version)
        } else {
            (None, 0)
        };
        let (bx, bl) = if spec.role.serves() {
            (
                vec![0.0f32; spec.max_batch * graph.input_elems],
                vec![0.0f32; spec.max_batch * graph.classes],
            )
        } else {
            (Vec::new(), Vec::new())
        };
        Ok(Tenant {
            spec,
            graph,
            plan,
            opt,
            train,
            serve,
            batch_x: bx,
            batch_logits: bl,
            steps: 0,
            served: 0,
            published,
        })
    }

    pub fn spec(&self) -> &TenantSpec {
        &self.spec
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Training steps executed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Inference requests served so far.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Snapshots published into the serve engine so far (== the
    /// serving snapshot's version).
    pub fn published(&self) -> u64 {
        self.published
    }

    pub fn train_engine(&self) -> Option<&(dyn StepEngine + Send)> {
        self.train.as_deref()
    }

    pub fn train_engine_mut(&mut self) -> Option<&mut (dyn StepEngine + Send)> {
        match &mut self.train {
            Some(t) => Some(t.as_mut()),
            None => None,
        }
    }

    pub fn serve_engine(&self) -> Option<&PackedInferEngine> {
        self.serve.as_ref()
    }

    /// One training step on a pre-staged batch.
    pub fn run_train(&mut self, x: &[f32], y: &[usize], lr: f32) -> Result<(f32, f32)> {
        let Some(t) = self.train.as_mut() else {
            bail!("tenant '{}' has no training role", self.spec.name)
        };
        let r = t.train_step(x, y, lr)?;
        self.steps += 1;
        Ok(r)
    }

    /// Run the serve engine on the first `n` staged rows of
    /// `batch_x`, leaving logits in `batch_logits`.
    pub fn run_infer(&mut self, n: usize) -> Result<()> {
        let Some(s) = self.serve.as_mut() else {
            bail!("tenant '{}' has no serving role", self.spec.name)
        };
        let ie = self.graph.input_elems;
        let cl = self.graph.classes;
        s.infer_into(&self.batch_x[..n * ie], n, &mut self.batch_logits[..n * cl])?;
        self.served += n as u64;
        Ok(())
    }

    /// Install an externally published snapshot (lane batch
    /// boundary).
    pub fn install_pending(&mut self, snap: Arc<WeightSnapshot>) -> Result<()> {
        let Some(s) = self.serve.as_mut() else {
            bail!("tenant '{}' has no serving role", self.spec.name)
        };
        self.published = snap.version();
        s.install(snap)?;
        Ok(())
    }

    /// `TrainServe` auto-publish: every `publish_every` steps, pack
    /// the latent weights (version = publish count) and install the
    /// snapshot into this tenant's own serve engine.  Returns the
    /// snapshot so callers (tests, the CLI demo) can observe it.
    pub fn maybe_autopublish(&mut self) -> Result<Option<Arc<WeightSnapshot>>> {
        let every = self.spec.publish_every;
        if every == 0 || !self.spec.role.serves() || self.steps % every as u64 != 0 {
            return Ok(None);
        }
        let Some(t) = self.train.as_ref() else { return Ok(None) };
        let v = self.published + 1;
        let snap = Arc::new(WeightSnapshot::pack(&self.plan, &t.weights_snapshot(), v)?);
        self.published = v;
        self.serve
            .as_mut()
            .expect("serves() checked above")
            .install(Arc::clone(&snap))?;
        Ok(Some(snap))
    }

    /// Measured steady-state bytes: train state+arena, serve
    /// snapshot+arena, and the staging buffers — the number
    /// [`crate::memmodel::fleet_envelope`] prices exactly.
    pub fn steady_state_bytes(&self) -> usize {
        let train = self
            .train
            .as_ref()
            .map(|t| t.state_bytes() + t.arena_bytes())
            .unwrap_or(0);
        let serve = self
            .serve
            .as_ref()
            .map(|s| s.state_bytes() + s.arena_bytes())
            .unwrap_or(0);
        train + serve + (self.batch_x.capacity() + self.batch_logits.capacity()) * 4
    }

    /// Both arenas quiescent — asserted at every lane hand-off.
    pub fn is_idle(&self) -> bool {
        self.train.as_ref().map(|t| t.arena_idle()).unwrap_or(true)
            && self.serve.as_ref().map(|s| s.arena_idle()).unwrap_or(true)
    }

    /// This tenant's load declaration for the fleet envelope.
    pub fn load(&self) -> memmodel::TenantLoad<'_> {
        memmodel::TenantLoad {
            graph: &self.graph,
            algo: &self.spec.algo,
            opt: self.opt,
            train: self.spec.role.trains().then_some((self.spec.batch, self.spec.microbatch)),
            serve: self.spec.role.serves().then_some(self.spec.max_batch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trainserve_tenant_publishes_its_own_weights() {
        let mut spec = TenantSpec::new("t", "mlp_mini", TenantRole::TrainServe);
        spec.batch = 8;
        spec.publish_every = 2;
        let mut t = Tenant::new(spec).unwrap();
        assert!(t.is_idle());
        assert_eq!(t.published(), 0);
        let ie = t.graph().input_elems;
        let mut rng = crate::util::rng::Pcg32::new(3);
        let x: Vec<f32> = rng.normal_vec(ie * 8);
        let y: Vec<usize> = (0..8).map(|i| i % t.graph().classes).collect();
        t.run_train(&x, &y, 0.01).unwrap();
        assert!(t.maybe_autopublish().unwrap().is_none(), "step 1 of 2");
        t.run_train(&x, &y, 0.01).unwrap();
        let snap = t.maybe_autopublish().unwrap().expect("step 2 publishes");
        assert_eq!(snap.version(), 1);
        assert_eq!(t.published(), 1);
        assert_eq!(t.serve_engine().unwrap().snapshot().version(), 1);
        // the published snapshot is exactly the trained weights
        let want = WeightSnapshot::pack(
            t.plan(),
            &t.train_engine().unwrap().weights_snapshot(),
            1,
        )
        .unwrap();
        assert_eq!(snap.bit_digest(), want.bit_digest());
        assert!(t.is_idle());
    }

    #[test]
    fn serve_only_tenant_runs_staged_batches() {
        let mut spec = TenantSpec::new("s", "mlp_mini", TenantRole::Serve);
        spec.max_batch = 4;
        let mut t = Tenant::new(spec).unwrap();
        assert!(t.train_engine().is_none());
        let ie = t.graph().input_elems;
        let cl = t.graph().classes;
        let mut rng = crate::util::rng::Pcg32::new(7);
        let x = rng.normal_vec(ie);
        t.batch_x[..ie].copy_from_slice(&x);
        t.run_infer(1).unwrap();
        assert_eq!(t.served(), 1);
        assert!(t.batch_logits[..cl].iter().all(|v| v.is_finite()));
        // identical to a solo engine on the same snapshot
        let mut solo = PackedInferEngine::new(
            t.graph(),
            InferAlgo::Proposed,
            Accel::Blocked,
            4,
            Arc::clone(t.serve_engine().unwrap().snapshot()),
        )
        .unwrap();
        let mut want = vec![0.0f32; cl];
        solo.infer_into(&x, 1, &mut want).unwrap();
        assert_eq!(&t.batch_logits[..cl], &want[..]);
        // steady state is priced exactly by the fleet envelope
        let env = memmodel::fleet_envelope(&[t.load()]).unwrap();
        assert_eq!(env.total_bytes() as usize, t.steady_state_bytes());
    }

    #[test]
    fn bad_specs_are_rejected() {
        let mut s = TenantSpec::new("x", "mlp_mini", TenantRole::Train);
        s.batch = 0;
        assert!(Tenant::new(s).is_err(), "zero batch");
        let mut s = TenantSpec::new("x", "mlp_mini", TenantRole::Train);
        s.batch = 8;
        s.microbatch = 3;
        assert!(Tenant::new(s).is_err(), "microbatch must divide");
        let mut s = TenantSpec::new("x", "mlp_mini", TenantRole::Serve);
        s.max_batch = 0;
        assert!(Tenant::new(s).is_err(), "zero max_batch");
        let mut s = TenantSpec::new("x", "mlp_mini", TenantRole::Serve);
        s.queue_cap = 2;
        assert!(Tenant::new(s).is_err(), "queue below max_batch");
    }
}
