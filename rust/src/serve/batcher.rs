//! Dynamic batcher: coalesces single-sample requests into
//! XNOR-GEMM-friendly batches under a latency SLO.
//!
//! Binary GEMM throughput scales strongly with rows (the packed
//! panels amortize weight traffic and fill the SIMD lanes), so
//! serving requests one by one wastes most of the kernel stack's
//! bandwidth.  The [`Batcher`] queues incoming requests; the
//! [`BatchServer`] loop drains up to `max_batch` of them per forward,
//! waiting at most `max_wait` after the first request of a batch
//! before running with whatever has arrived — the classic
//! max-batch + max-wait SLO policy.
//!
//! ## Zero-allocation steady state
//!
//! A request is three raw pointers into the *client's* buffers
//! (input, logits out, done flag) pushed into a pre-sized `VecDeque`;
//! the server gathers inputs into a pre-sized staging buffer, runs
//! the warmed [`PackedInferEngine`] (allocation-free by itself), and
//! scatters logits back under the queue lock.  No step of the
//! request path touches the heap (hard-asserted in
//! rust/tests/memtrack_serve.rs), and the worker threads driving the
//! GEMM are the *process-global* `bitops::Pool` set, so a serve loop
//! composes with a concurrently-running trainer instead of
//! oversubscribing cores.
//!
//! ## Safety of the pointer protocol
//!
//! `infer_one` blocks until the server sets the request's done flag,
//! so the pointed-to client buffers outlive every server access.
//! Output writes and the done-flag store happen under the queue
//! mutex, and the client re-checks the flag under the same mutex —
//! the lock provides the happens-before edge; the flag is atomic only
//! so both sides may touch it through a shared pointer.
//!
//! ## Snapshot hot-swap
//!
//! [`Batcher::publish`] parks a new [`WeightSnapshot`]; the server
//! installs it at the next *batch boundary*.  Every batch therefore
//! runs against exactly one snapshot — concurrent clients observe
//! old-or-new results, never a mix (pinned in
//! rust/tests/serve_parity.rs).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::engine::PackedInferEngine;
use super::snapshot::WeightSnapshot;

/// One queued request: pointers into the blocked client's buffers.
struct Req {
    x: *const f32,
    out: *mut f32,
    done: *const AtomicBool,
}

// The client blocks in `infer_one` until `done` is set, so the
// pointees outlive every server access (see module docs).
unsafe impl Send for Req {}

struct QState {
    queue: VecDeque<Req>,
    shutdown: bool,
    served: u64,
}

struct Shared {
    m: Mutex<QState>,
    /// A request was enqueued (server wakes to form a batch).
    submitted: Condvar,
    /// A batch completed (clients re-check their done flags).
    completed: Condvar,
    /// Queue space freed (back-pressured clients retry).
    space: Condvar,
    /// Parked by `publish`, installed at the next batch boundary.
    pending_snap: Mutex<Option<Arc<WeightSnapshot>>>,
    input_elems: usize,
    classes: usize,
    max_batch: usize,
    max_wait: Duration,
    queue_cap: usize,
}

/// Client + publisher handle to a running [`BatchServer`] (cheap to
/// clone; one per client thread).
#[derive(Clone)]
pub struct Batcher {
    sh: Arc<Shared>,
}

impl Batcher {
    /// Submit one sample and block until its logits arrive.  `x` is
    /// `input_elems` long, `out` receives `classes` logits.
    /// Allocation-free.
    pub fn infer_one(&self, x: &[f32], out: &mut [f32]) -> Result<()> {
        if x.len() != self.sh.input_elems {
            bail!("input is {} elems, want {}", x.len(), self.sh.input_elems);
        }
        if out.len() != self.sh.classes {
            bail!("output is {} elems, want {}", out.len(), self.sh.classes);
        }
        let done = AtomicBool::new(false);
        let req = Req { x: x.as_ptr(), out: out.as_mut_ptr(), done: &done };
        let mut q = self.sh.m.lock().unwrap();
        while q.queue.len() >= self.sh.queue_cap && !q.shutdown {
            q = self.sh.space.wait(q).unwrap();
        }
        if q.shutdown {
            bail!("batcher is shut down");
        }
        q.queue.push_back(req);
        self.sh.submitted.notify_one();
        // once enqueued we *must* wait for completion (the server owns
        // our pointers until it sets done); shutdown drains the queue
        while !done.load(Ordering::Relaxed) {
            q = self.sh.completed.wait(q).unwrap();
        }
        Ok(())
    }

    /// Park a freshly packed snapshot for installation at the next
    /// batch boundary (copy-on-publish: in-flight batches finish on
    /// the old one).
    pub fn publish(&self, snap: Arc<WeightSnapshot>) {
        *self.sh.pending_snap.lock().unwrap() = Some(snap);
    }

    /// Stop accepting requests; the server drains what is queued and
    /// exits its loop.
    pub fn shutdown(&self) {
        self.sh.m.lock().unwrap().shutdown = true;
        self.sh.submitted.notify_all();
        self.sh.space.notify_all();
    }

    /// Total requests completed so far.
    pub fn served(&self) -> u64 {
        self.sh.m.lock().unwrap().served
    }
}

/// The serve loop: owns the warmed engine and the staging buffers.
/// Build with [`BatchServer::new`], move to a thread, call
/// [`BatchServer::run`].
pub struct BatchServer {
    engine: PackedInferEngine,
    sh: Arc<Shared>,
    /// Gather buffer, `max_batch × input_elems`.
    batch_x: Vec<f32>,
    /// Scatter buffer, `max_batch × classes`.
    batch_logits: Vec<f32>,
    /// The batch being executed (drained out of the queue so clients
    /// can keep enqueueing while the forward runs).
    pending: Vec<Req>,
}

impl BatchServer {
    /// Wrap a [`PackedInferEngine`] (warmed up here — its `max_batch`
    /// is the batch cap) with a request queue of `queue_cap` entries
    /// and a `max_wait_us` coalescing window.
    pub fn new(
        mut engine: PackedInferEngine,
        max_wait_us: u64,
        queue_cap: usize,
    ) -> Result<(Batcher, BatchServer)> {
        let max_batch = engine.max_batch();
        if queue_cap < max_batch {
            bail!("queue_cap {queue_cap} below max_batch {max_batch}");
        }
        engine.warmup()?;
        let sh = Arc::new(Shared {
            m: Mutex::new(QState {
                queue: VecDeque::with_capacity(queue_cap),
                shutdown: false,
                served: 0,
            }),
            submitted: Condvar::new(),
            completed: Condvar::new(),
            space: Condvar::new(),
            pending_snap: Mutex::new(None),
            input_elems: engine.input_elems(),
            classes: engine.classes(),
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
            queue_cap,
        });
        let server = BatchServer {
            batch_x: vec![0.0; max_batch * engine.input_elems()],
            batch_logits: vec![0.0; max_batch * engine.classes()],
            pending: Vec::with_capacity(max_batch),
            engine,
            sh: Arc::clone(&sh),
        };
        Ok((Batcher { sh }, server))
    }

    /// Steady-state resident bytes of the serve loop: snapshot +
    /// scratch arena + staging buffers.
    pub fn steady_state_bytes(&self) -> usize {
        self.engine.state_bytes()
            + self.engine.arena_bytes()
            + (self.batch_x.capacity() + self.batch_logits.capacity()) * 4
    }

    /// Serve until shutdown; returns the engine (with whatever
    /// snapshot ended up installed) once the queue is drained.
    pub fn run(mut self) -> Result<PackedInferEngine> {
        loop {
            let n = {
                let mut q = self.sh.m.lock().unwrap();
                while q.queue.is_empty() && !q.shutdown {
                    q = self.sh.submitted.wait(q).unwrap();
                }
                if q.queue.is_empty() {
                    // shutdown + drained: a snapshot published after
                    // the last batch is still parked — install it so
                    // the returned engine (and anything that restarts
                    // from it) serves the newest weights instead of
                    // silently dropping the publish
                    drop(q);
                    if let Some(s) = self.sh.pending_snap.lock().unwrap().take() {
                        self.engine.install(s)?;
                    }
                    return Ok(self.engine);
                }
                // SLO window: wait for more requests, at most
                // max_wait past the first one seen
                let start = Instant::now();
                while q.queue.len() < self.sh.max_batch && !q.shutdown {
                    let elapsed = start.elapsed();
                    if elapsed >= self.sh.max_wait {
                        break;
                    }
                    let (g, t) = self
                        .sh
                        .submitted
                        .wait_timeout(q, self.sh.max_wait - elapsed)
                        .unwrap();
                    q = g;
                    if t.timed_out() {
                        break;
                    }
                }
                let take = q.queue.len().min(self.sh.max_batch);
                for _ in 0..take {
                    self.pending.push(q.queue.pop_front().unwrap());
                }
                self.sh.space.notify_all();
                take
            };
            // batch boundary: install a published snapshot, so every
            // request of this batch sees exactly one weight version
            if let Some(s) = self.sh.pending_snap.lock().unwrap().take() {
                self.engine.install(s)?;
            }
            let ie = self.sh.input_elems;
            let cl = self.sh.classes;
            for (i, r) in self.pending.iter().enumerate() {
                let src = unsafe { std::slice::from_raw_parts(r.x, ie) };
                self.batch_x[i * ie..(i + 1) * ie].copy_from_slice(src);
            }
            self.engine
                .infer_into(&self.batch_x[..n * ie], n, &mut self.batch_logits[..n * cl])?;
            {
                let mut q = self.sh.m.lock().unwrap();
                for (i, r) in self.pending.iter().enumerate() {
                    let dst = unsafe { std::slice::from_raw_parts_mut(r.out, cl) };
                    dst.copy_from_slice(&self.batch_logits[i * cl..(i + 1) * cl]);
                    unsafe { (*r.done).store(true, Ordering::Relaxed) };
                }
                q.served += n as u64;
            }
            self.sh.completed.notify_all();
            self.pending.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};
    use crate::naive::{build_engine, Accel, Plan, StepEngine};
    use crate::serve::engine::InferAlgo;
    use crate::util::rng::Pcg32;

    fn mini_engine(algo: InferAlgo, max_batch: usize) -> (PackedInferEngine, PackedInferEngine) {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let trainer = build_engine("standard", &graph, 4, "adam", Accel::Blocked, 7).unwrap();
        let snap =
            Arc::new(WeightSnapshot::pack(&plan, &trainer.weights_snapshot(), 1).unwrap());
        let a =
            PackedInferEngine::new(&graph, algo, Accel::Blocked, max_batch, Arc::clone(&snap))
                .unwrap();
        let b = PackedInferEngine::new(&graph, algo, Accel::Blocked, max_batch, snap).unwrap();
        (a, b)
    }

    #[test]
    fn single_client_round_trips_match_direct_inference() {
        // sequential requests with a tiny wait window ⇒ every batch
        // is size 1 ⇒ results must equal direct batch-1 inference
        let (engine, mut reference) = mini_engine(InferAlgo::Standard, 4);
        let ie = engine.input_elems();
        let cl = engine.classes();
        let (batcher, server) = BatchServer::new(engine, 50, 16).unwrap();
        let h = std::thread::spawn(move || server.run());
        let mut rng = Pcg32::new(11);
        for _ in 0..8 {
            let x = rng.normal_vec(ie);
            let mut got = vec![0.0f32; cl];
            batcher.infer_one(&x, &mut got).unwrap();
            let mut want = vec![0.0f32; cl];
            reference.infer_into(&x, 1, &mut want).unwrap();
            assert_eq!(got, want);
        }
        assert_eq!(batcher.served(), 8);
        batcher.shutdown();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn concurrent_clients_all_complete() {
        let (engine, _) = mini_engine(InferAlgo::Proposed, 8);
        let ie = engine.input_elems();
        let cl = engine.classes();
        let (batcher, server) = BatchServer::new(engine, 200, 32).unwrap();
        let h = std::thread::spawn(move || server.run());
        let mut clients = Vec::new();
        for t in 0..4u64 {
            let b = batcher.clone();
            clients.push(std::thread::spawn(move || {
                let mut rng = Pcg32::new(100 + t);
                let mut out = vec![0.0f32; cl];
                for _ in 0..12 {
                    let x = rng.normal_vec(ie);
                    b.infer_one(&x, &mut out).unwrap();
                    assert!(out.iter().all(|v| v.is_finite()));
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        assert_eq!(batcher.served(), 4 * 12);
        batcher.shutdown();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn shutdown_installs_parked_snapshot() {
        // regression: a snapshot published after the last served
        // batch used to be dropped by the shutdown drain — the
        // returned engine kept serving stale weights
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let t0 = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 3).unwrap();
        let snap0 = Arc::new(WeightSnapshot::pack(&plan, &t0.weights_snapshot(), 0).unwrap());
        let t1 = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 99).unwrap();
        let snap1 = Arc::new(WeightSnapshot::pack(&plan, &t1.weights_snapshot(), 1).unwrap());
        let engine =
            PackedInferEngine::new(&graph, InferAlgo::Proposed, Accel::Blocked, 1, snap0)
                .unwrap();
        let (batcher, server) = BatchServer::new(engine, 50, 4).unwrap();
        let h = std::thread::spawn(move || server.run());
        batcher.publish(Arc::clone(&snap1));
        batcher.shutdown();
        let engine = h.join().unwrap().unwrap();
        assert_eq!(
            engine.snapshot().version(),
            1,
            "publish-then-shutdown must install the parked snapshot"
        );
        assert_eq!(engine.snapshot().bit_digest(), snap1.bit_digest());
    }

    #[test]
    fn publish_swaps_at_batch_boundary_and_shutdown_rejects_new_requests() {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let t0 = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 3).unwrap();
        let snap0 = Arc::new(WeightSnapshot::pack(&plan, &t0.weights_snapshot(), 0).unwrap());
        let t1 = build_engine("proposed", &graph, 4, "adam", Accel::Blocked, 99).unwrap();
        let snap1 = Arc::new(WeightSnapshot::pack(&plan, &t1.weights_snapshot(), 1).unwrap());

        let mk = |snap: &Arc<WeightSnapshot>| {
            PackedInferEngine::new(&graph, InferAlgo::Proposed, Accel::Blocked, 1, Arc::clone(snap))
                .unwrap()
        };
        let engine = mk(&snap0);
        let ie = engine.input_elems();
        let cl = engine.classes();
        let (batcher, server) = BatchServer::new(engine, 50, 4).unwrap();
        let h = std::thread::spawn(move || server.run());

        let mut rng = Pcg32::new(5);
        let x = rng.normal_vec(ie);
        let mut want0 = vec![0.0f32; cl];
        mk(&snap0).infer_into(&x, 1, &mut want0).unwrap();
        let mut want1 = vec![0.0f32; cl];
        mk(&snap1).infer_into(&x, 1, &mut want1).unwrap();
        assert_ne!(want0, want1, "differently seeded weights must differ");

        let mut got = vec![0.0f32; cl];
        batcher.infer_one(&x, &mut got).unwrap();
        assert_eq!(got, want0);
        batcher.publish(Arc::clone(&snap1));
        batcher.infer_one(&x, &mut got).unwrap();
        assert_eq!(got, want1, "published snapshot applies at the next batch");

        batcher.shutdown();
        let engine = h.join().unwrap().unwrap();
        assert_eq!(engine.snapshot().version(), 1);
        assert!(batcher.infer_one(&x, &mut got).is_err());
    }
}
