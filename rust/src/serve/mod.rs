//! Packed inference serving: the deployment-side front door.
//!
//! The paper's binary format is as much an inference win as a
//! training one — this module turns the PR 1–5 kernel stack
//! (bit-packed im2col, SIMD XNOR-popcount GEMM, the fused conv
//! pipeline) into a forward-only serving path with three pieces:
//!
//! - [`PackedInferEngine`] — lowers a model [`crate::naive::Plan`]
//!   into an inference-only schedule: no retained activations, no
//!   gradient transients, one reusable scratch arena.  After
//!   [`PackedInferEngine::warmup`] a forward pass at any batch size
//!   performs **zero heap allocations**, and its logits are
//!   bit-identical to the training engines' `eval` on the same tier.
//! - [`Batcher`] / [`BatchServer`] — dynamic batching: single-sample
//!   requests coalesce into XNOR-friendly batches under a
//!   max-batch + max-wait SLO, on the process-global `bitops::Pool`
//!   workers (composing with, not oversubscribing, a concurrent
//!   trainer).
//! - [`WeightSnapshot`] — copy-on-publish weights: a training loop
//!   `publish`es an immutable `Arc`-shared packed snapshot; the
//!   server installs it at a batch boundary while in-flight requests
//!   finish on the old one.
//!
//! Note the BN layers use *batch statistics* (no running stats — both
//! training algorithms are defined that way), so coalescing couples
//! the samples of one batch through BN: dynamic batching trades exact
//! batch-1 reproducibility for throughput.  Parity with the trainers
//! is defined — and pinned, in rust/tests/serve_parity.rs — on
//! identical batches.
//!
//! `bnn-edge serve` (see `coordinator`) runs a self-driving load demo
//! over this stack; `benches/perf_serve.rs` measures p50/p99 latency
//! and throughput vs offered load, and CI gates on dynamic batching
//! beating serial batch-1 serving.

mod batcher;
mod engine;
mod snapshot;

pub use batcher::{BatchServer, Batcher};
pub use engine::{InferAlgo, PackedInferEngine};
pub use snapshot::{LayerWeights, WeightSnapshot};
