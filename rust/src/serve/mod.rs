//! Packed inference serving: the deployment-side front door.
//!
//! The paper's binary format is as much an inference win as a
//! training one — this module turns the PR 1–5 kernel stack
//! (bit-packed im2col, SIMD XNOR-popcount GEMM, the fused conv
//! pipeline) into a forward-only serving path with three pieces:
//!
//! - [`PackedInferEngine`] — lowers a model [`crate::naive::Plan`]
//!   into an inference-only schedule: no retained activations, no
//!   gradient transients, one reusable scratch arena.  After
//!   [`PackedInferEngine::warmup`] a forward pass at any batch size
//!   performs **zero heap allocations**, and its logits are
//!   bit-identical to the training engines' `eval` on the same tier.
//! - [`Batcher`] / [`BatchServer`] — dynamic batching: single-sample
//!   requests coalesce into XNOR-friendly batches under a
//!   max-batch + max-wait SLO, on the process-global `bitops::Pool`
//!   workers (composing with, not oversubscribing, a concurrent
//!   trainer).
//! - [`WeightSnapshot`] — copy-on-publish weights: a training loop
//!   `publish`es an immutable `Arc`-shared packed snapshot; the
//!   server installs it at a batch boundary while in-flight requests
//!   finish on the old one.
//!
//! Note the BN layers use *batch statistics* (no running stats — both
//! training algorithms are defined that way), so coalescing couples
//! the samples of one batch through BN: dynamic batching trades exact
//! batch-1 reproducibility for throughput.  Parity with the trainers
//! is defined — and pinned, in rust/tests/serve_parity.rs — on
//! identical batches.
//!
//! `bnn-edge serve` (see `coordinator`) runs a self-driving load demo
//! over this stack; `benches/perf_serve.rs` measures p50/p99 latency
//! and throughput vs offered load, and CI gates on dynamic batching
//! beating serial batch-1 serving.
//!
//! On top of the single-engine stack sits the **multi-tenant
//! runtime** ([`MultiModelServer`]): N [`Tenant`]s — each a compiled
//! train and/or serve schedule with its own slot arena and snapshot
//! chain — co-scheduled by a work-conserving round-robin interleaver
//! on lanes that share the process-global worker pool, with live
//! train-and-serve (periodic copy-on-publish from a tenant's trainer
//! into its own serve engine) and a planned
//! [`crate::memmodel::fleet_envelope`] that equals the measured
//! steady state exactly.  `bnn-edge multi` demos it;
//! `benches/perf_multi.rs` + `BENCH_multi.json` carry the
//! co-scheduled vs time-sliced headline, CI-gated at ≥1.5×.

mod batcher;
mod engine;
mod multi;
mod snapshot;
mod tenant;

pub use batcher::{BatchServer, Batcher};
pub use engine::{InferAlgo, PackedInferEngine};
pub use multi::{MultiClient, MultiModelServer};
pub use snapshot::{LayerWeights, WeightSnapshot};
pub use tenant::{Tenant, TenantRole, TenantSpec};
