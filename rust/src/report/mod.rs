//! Paper-style table/series rendering: every bench funnels its
//! results through here so stdout and `results/*.md` look like the
//! paper's tables.

use std::path::Path;

use anyhow::Result;

use crate::memmodel::Breakdown;
use crate::util::table::{factor, f, pp, Align, Table};
use crate::util::MIB;

/// Table 2: per-variable breakdown, standard vs proposed.
pub fn table2(std: &Breakdown, prop: &Breakdown) -> String {
    let mut t = Table::new(
        &format!(
            "Table 2 — {} training memory (B={})",
            std.model, std.batch
        ),
        &["Variable", "Std dtype", "Std MiB", "Prop dtype", "Prop MiB", "delta"],
    )
    .align(0, Align::Left);
    for row in &std.rows {
        let p = prop.row(row.name);
        let (pd, pm, delta) = match p {
            Some(p) => (
                p.dtype.name().to_string(),
                f(p.bytes / MIB, 2),
                factor(row.bytes / p.bytes),
            ),
            None => ("-".into(), "-".into(), "-".into()),
        };
        t.row(&[
            row.name.to_string(),
            row.dtype.name().to_string(),
            f(row.bytes / MIB, 2),
            pd,
            pm,
            delta,
        ]);
    }
    t.row(&[
        "Total".to_string(),
        String::new(),
        f(std.total_mib(), 2),
        String::new(),
        f(prop.total_mib(), 2),
        factor(std.total_bytes() / prop.total_bytes()),
    ]);
    t.to_markdown()
}

/// Accuracy-delta row formatting (Tables 3-6): value + Δpp column.
pub struct AccRow {
    pub label: String,
    pub baseline_acc: f32,
    pub acc: f32,
    pub mib: Option<f64>,
    pub mib_factor: Option<f64>,
}

pub fn acc_table(title: &str, rows: &[AccRow]) -> String {
    let mut t = Table::new(
        title,
        &["Configuration", "Acc %", "delta pp", "Modeled MiB", "delta x"],
    )
    .align(0, Align::Left);
    for r in rows {
        t.row(&[
            r.label.clone(),
            f(r.acc as f64 * 100.0, 2),
            pp((r.acc - r.baseline_acc) as f64 * 100.0),
            r.mib.map(|m| f(m, 2)).unwrap_or_else(|| "-".into()),
            r.mib_factor.map(factor).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.to_markdown()
}

/// (x, series...) curves as a markdown table (Figs. 2/3/4/5/6/7).
pub fn series_table(
    title: &str,
    x_label: &str,
    series_labels: &[&str],
    points: &[(f64, Vec<Option<f64>>)],
    decimals: usize,
) -> String {
    let mut header = vec![x_label];
    header.extend_from_slice(series_labels);
    let mut t = Table::new(title, &header);
    for (x, ys) in points {
        let mut row = vec![f(*x, 0)];
        for y in ys {
            row.push(y.map(|v| f(v, decimals)).unwrap_or_else(|| "-".into()));
        }
        t.row(&row);
    }
    t.to_markdown()
}

/// Append a rendered section to results/<file> (creating dirs).
pub fn write_section<P: AsRef<Path>>(path: P, content: &str) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, content)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::{breakdown, DtypeConfig, Optimizer};
    use crate::models::{get, lower};

    #[test]
    fn table2_renders() {
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let s = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam);
        let p = breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Adam);
        let md = table2(&s, &p);
        assert!(md.contains("| X "));
        assert!(md.contains("512.8"));
        assert!(md.contains("138.")); // total
        assert!(md.contains("32.00x")); // X reduction
    }

    #[test]
    fn acc_table_renders_deltas() {
        let rows = vec![
            AccRow {
                label: "standard".into(),
                baseline_acc: 0.887,
                acc: 0.887,
                mib: Some(512.81),
                mib_factor: None,
            },
            AccRow {
                label: "proposed".into(),
                baseline_acc: 0.887,
                acc: 0.891,
                mib: Some(138.15),
                mib_factor: Some(3.71),
            },
        ];
        let md = acc_table("Table 4", &rows);
        assert!(md.contains("+0.40"));
        assert!(md.contains("3.71x"));
    }

    #[test]
    fn series_renders_gaps() {
        let md = series_table(
            "Fig 2",
            "batch",
            &["std", "prop"],
            &[(16.0, vec![Some(1.0), Some(2.0)]), (64.0, vec![None, Some(3.0)])],
            1,
        );
        assert!(md.contains("| -"));
    }
}
