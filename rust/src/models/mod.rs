//! Model zoo + shape inference.
//!
//! Mirrors `python/compile/models.py` exactly for the `*_mini`
//! variants (the AOT-executable ones) and additionally provides the
//! *full-scale* paper models — MLP, CNV, BinaryNet, ResNetE-18,
//! Bi-Real-18 — whose lowered graphs drive the memory model (Table 2,
//! Table 6), the naive engines, and the energy model.
//!
//! `lower()` turns a [`ModelSpec`] into a flat [`Graph`] of per-layer
//! nodes with concrete per-sample element counts: everything the
//! variable representation & lifetime analysis (Sec. 4) needs.

mod zoo;

pub use zoo::{get, names};

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Dense,
    Conv,
    MaxPool,
    GlobalPool,
    Flatten,
    /// Residual skip wrapper around 1 (Bi-Real) or 2 (ResNetE) convs;
    /// lowered to the convs it contains, plus an f32 skip buffer.
    ResidualMarker,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Padding {
    /// Zero-pad so output spatial = ceil(input / stride) (BinaryNet).
    #[default]
    Same,
    /// No padding: output = (input - kernel)/stride + 1 (FINN CNV).
    Valid,
}

/// Author-facing layer description.
#[derive(Clone, Copy, Debug)]
pub struct LayerSpec {
    pub kind: LayerKind,
    pub out: usize,
    pub kernel: usize,
    pub stride: usize,
    pub first: bool,
    pub bireal: bool,
    pub pad: Padding,
}

impl LayerSpec {
    pub fn dense(out: usize) -> LayerSpec {
        LayerSpec { kind: LayerKind::Dense, out, kernel: 0, stride: 1, first: false, bireal: false, pad: Padding::Same }
    }

    pub fn conv(out: usize, kernel: usize) -> LayerSpec {
        LayerSpec { kind: LayerKind::Conv, out, kernel, stride: 1, first: false, bireal: false, pad: Padding::Same }
    }

    pub fn conv_s(out: usize, kernel: usize, stride: usize) -> LayerSpec {
        LayerSpec { stride, ..LayerSpec::conv(out, kernel) }
    }

    pub fn maxpool() -> LayerSpec {
        LayerSpec::maxpool_k(2, 2)
    }

    /// General `kside`×`kside` stride-`stride` max-pool (VALID floor
    /// geometry: out = (in − kside)/stride + 1).
    pub fn maxpool_k(kside: usize, stride: usize) -> LayerSpec {
        LayerSpec { kind: LayerKind::MaxPool, out: 0, kernel: kside, stride, first: false, bireal: false, pad: Padding::Valid }
    }

    pub fn global_pool() -> LayerSpec {
        LayerSpec { kind: LayerKind::GlobalPool, out: 0, kernel: 0, stride: 1, first: false, bireal: false, pad: Padding::Valid }
    }

    pub fn flatten() -> LayerSpec {
        LayerSpec { kind: LayerKind::Flatten, out: 0, kernel: 0, stride: 1, first: false, bireal: false, pad: Padding::Valid }
    }

    pub fn residual(out: usize, kernel: usize, stride: usize, bireal: bool) -> LayerSpec {
        LayerSpec { kind: LayerKind::ResidualMarker, out, kernel, stride, first: false, bireal, pad: Padding::Same }
    }

    pub fn as_first(mut self) -> LayerSpec {
        self.first = true;
        self
    }

    pub fn valid(mut self) -> LayerSpec {
        self.pad = Padding::Valid;
        self
    }
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Per-sample input shape: `[feat]` (MLP) or `[h, w, c]`.
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub layers: Vec<LayerSpec>,
}

/// Explicit spatial geometry of a Conv / MaxPool / GlobalPool node,
/// recorded at lowering time so downstream consumers (the naive
/// engines' `Plan`, the memory model) never re-infer dims by isqrt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeGeom {
    /// Input spatial dims and channels.
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    /// Output spatial dims (GlobalPool: 1×1).
    pub oh: usize,
    pub ow: usize,
    /// Kernel side (GlobalPool: 0 — the whole map).
    pub kside: usize,
    /// Spatial stride.
    pub stride: usize,
    pub pad: Padding,
}

/// One lowered compute node — the unit the memory/energy models and
/// the naive engines operate on.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: LayerKind,
    /// Per-sample elements entering this node (the `X_l` the paper
    /// retains between forward and backward propagation).
    pub in_elems: usize,
    /// Per-sample elements leaving (`Y_l` for matmul nodes).
    pub out_elems: usize,
    /// Weight elements (0 for pool/flatten).
    pub w_elems: usize,
    /// Output channels (batch-norm statistic rows).
    pub channels: usize,
    /// Fan-in `N_l` (the Alg. 2 line-18 attenuation divisor).
    pub fan_in: usize,
    /// GEMM dims per sample: (m, k, n) of the im2col matmul.
    pub gemm: (usize, usize, usize),
    /// True if this layer consumes unquantized inputs (first layer).
    pub first: bool,
    /// True if wrapped in a high-precision residual skip.
    pub in_residual: bool,
    /// Spatial geometry (None for dense/flatten nodes).
    pub geom: Option<NodeGeom>,
    /// Opens a residual block: the f32 skip is saved from this node's
    /// input (set on the block's first conv).
    pub skip_open: bool,
    /// Closes a residual block: the (downsampled) skip is added after
    /// this node's batch norm (set on the block's last conv; for
    /// Bi-Real single-conv blocks the same node opens and closes).
    pub skip_close: bool,
}

impl Node {
    pub fn is_matmul(&self) -> bool {
        matches!(self.kind, LayerKind::Dense | LayerKind::Conv)
    }
}

/// Lowered graph: nodes in execution order + bookkeeping.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub input_elems: usize,
    pub classes: usize,
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Total weight elements (the paper's `W`).
    pub fn total_weights(&self) -> usize {
        self.nodes.iter().map(|n| n.w_elems).sum()
    }

    /// Total batch-norm channels (β, µ, ψ, ω rows).
    pub fn total_channels(&self) -> usize {
        self.nodes.iter().map(|n| n.channels).sum()
    }

    /// Per-sample retained activation elements: ALL matmul-layer
    /// inputs, including the first (the paper's Table 2 `X` row counts
    /// the input batch too — verified against its 111.33 MiB).
    pub fn retained_act_elems(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.is_matmul())
            .map(|n| n.in_elems)
            .sum()
    }

    /// Per-sample elements of the largest matmul output — `Y`/`∂X`
    /// and `∂Y` are transient and sized by the *largest* layer.
    pub fn max_y_elems(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.is_matmul())
            .map(|n| n.out_elems)
            .max()
            .unwrap_or(0)
    }

    /// Per-sample max-pool mask elements (sized by pool inputs).
    pub fn pool_mask_elems(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.kind == LayerKind::MaxPool)
            .map(|n| n.in_elems)
            .sum()
    }

    /// Per-sample f32 residual-skip buffer elements (largest skip
    /// alive at once; ResNetE/Bi-Real keep skips high-precision).
    pub fn residual_skip_elems(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.in_residual)
            .map(|n| n.in_elems)
            .max()
            .unwrap_or(0)
    }

    /// Multiply-accumulate count per sample (forward pass).
    pub fn macs(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| {
                let (m, k, nn) = n.gemm;
                m * k * nn
            })
            .sum()
    }
}

/// Shape-infer a [`ModelSpec`] into a [`Graph`].
pub fn lower(spec: &ModelSpec) -> Result<Graph> {
    let mut nodes = Vec::new();
    let (mut feat, mut spatial, mut ch): (usize, Option<(usize, usize)>, usize);
    match spec.input_shape.as_slice() {
        [f] => {
            feat = *f;
            spatial = None;
            ch = 0;
        }
        [h, w, c] => {
            feat = 0;
            spatial = Some((*h, *w));
            ch = *c;
        }
        other => bail!("bad input shape {other:?}"),
    }
    let input_elems: usize = spec.input_shape.iter().product();

    fn push_conv(
        nodes: &mut Vec<Node>,
        l: &LayerSpec,
        spatial: &mut Option<(usize, usize)>,
        ch: &mut usize,
        out: usize,
        in_residual: bool,
        skip: (bool, bool),
    ) -> Result<()> {
        let (h, w) = spatial.ok_or_else(|| anyhow::anyhow!("conv without spatial dims"))?;
        if l.kernel == 0 || l.stride == 0 {
            bail!("conv kernel/stride must be positive (k={}, s={})", l.kernel, l.stride);
        }
        let (oh, ow) = match l.pad {
            Padding::Same => (h.div_ceil(l.stride), w.div_ceil(l.stride)),
            Padding::Valid => {
                if l.kernel > h || l.kernel > w {
                    bail!("VALID conv kernel {} exceeds input {h}x{w}", l.kernel);
                }
                ((h - l.kernel) / l.stride + 1, (w - l.kernel) / l.stride + 1)
            }
        };
        let k = l.kernel * l.kernel * *ch;
        nodes.push(Node {
            kind: LayerKind::Conv,
            in_elems: h * w * *ch,
            out_elems: oh * ow * out,
            w_elems: k * out,
            channels: out,
            fan_in: k,
            gemm: (oh * ow, k, out),
            first: l.first,
            in_residual,
            geom: Some(NodeGeom {
                h,
                w,
                c_in: *ch,
                oh,
                ow,
                kside: l.kernel,
                stride: l.stride,
                pad: l.pad,
            }),
            skip_open: skip.0,
            skip_close: skip.1,
        });
        *spatial = Some((oh, ow));
        *ch = out;
        Ok(())
    }

    for l in &spec.layers {
        match l.kind {
            LayerKind::Dense => {
                let in_feat = if feat == 0 {
                    let (h, w) = spatial.take().unwrap();
                    h * w * ch
                } else {
                    feat
                };
                nodes.push(Node {
                    kind: LayerKind::Dense,
                    in_elems: in_feat,
                    out_elems: l.out,
                    w_elems: in_feat * l.out,
                    channels: l.out,
                    fan_in: in_feat,
                    gemm: (1, in_feat, l.out),
                    first: l.first,
                    in_residual: false,
                    geom: None,
                    skip_open: false,
                    skip_close: false,
                });
                feat = l.out;
            }
            LayerKind::Conv => {
                push_conv(&mut nodes, l, &mut spatial, &mut ch, l.out, false, (false, false))?;
            }
            LayerKind::ResidualMarker => {
                // 1 conv (Bi-Real) or 2 convs (ResNetE) inside a skip:
                // the first conv opens the block (its input is the
                // saved f32 skip), the last closes it (the skip is
                // added after its batch norm)
                let mut inner = *l;
                inner.kind = LayerKind::Conv;
                let close = l.bireal; // single-conv block opens+closes
                push_conv(&mut nodes, &inner, &mut spatial, &mut ch, l.out, true, (true, close))?;
                if !l.bireal {
                    let mut second = inner;
                    second.stride = 1;
                    push_conv(
                        &mut nodes,
                        &second,
                        &mut spatial,
                        &mut ch,
                        l.out,
                        true,
                        (false, true),
                    )?;
                }
            }
            LayerKind::MaxPool => {
                let (h, w) = spatial.unwrap();
                if l.kernel == 0 || l.stride == 0 || l.kernel > h || l.kernel > w {
                    bail!(
                        "max-pool kernel/stride (k={}, s={}) invalid for a {h}x{w} map",
                        l.kernel,
                        l.stride
                    );
                }
                let (oh, ow) = ((h - l.kernel) / l.stride + 1, (w - l.kernel) / l.stride + 1);
                nodes.push(Node {
                    kind: LayerKind::MaxPool,
                    in_elems: h * w * ch,
                    out_elems: oh * ow * ch,
                    w_elems: 0,
                    channels: 0,
                    fan_in: 0,
                    gemm: (0, 0, 0),
                    first: false,
                    in_residual: false,
                    geom: Some(NodeGeom {
                        h,
                        w,
                        c_in: ch,
                        oh,
                        ow,
                        kside: l.kernel,
                        stride: l.stride,
                        pad: Padding::Valid,
                    }),
                    skip_open: false,
                    skip_close: false,
                });
                spatial = Some((oh, ow));
            }
            LayerKind::GlobalPool => {
                let (h, w) = spatial.unwrap();
                nodes.push(Node {
                    kind: LayerKind::GlobalPool,
                    in_elems: h * w * ch,
                    out_elems: ch,
                    w_elems: 0,
                    channels: 0,
                    fan_in: 0,
                    gemm: (0, 0, 0),
                    first: false,
                    in_residual: false,
                    geom: Some(NodeGeom {
                        h,
                        w,
                        c_in: ch,
                        oh: 1,
                        ow: 1,
                        kside: 0,
                        stride: 1,
                        pad: Padding::Valid,
                    }),
                    skip_open: false,
                    skip_close: false,
                });
                spatial = None;
                feat = ch;
            }
            LayerKind::Flatten => {
                if let Some((h, w)) = spatial.take() {
                    feat = h * w * ch;
                }
                nodes.push(Node {
                    kind: LayerKind::Flatten,
                    in_elems: feat,
                    out_elems: feat,
                    w_elems: 0,
                    channels: 0,
                    fan_in: 0,
                    gemm: (0, 0, 0),
                    first: false,
                    in_residual: false,
                    geom: None,
                    skip_open: false,
                    skip_close: false,
                });
            }
        }
    }
    Ok(Graph {
        name: spec.name.clone(),
        input_elems,
        classes: spec.classes,
        nodes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binarynet_matches_paper_table2() {
        // Table 2 cross-check (B=100, f32): W = 53.49 MiB, X = 111.33
        // MiB, Y/∂X = 50.00 MiB, pool masks = 87.46 MiB.
        let g = lower(&zoo::get("binarynet").unwrap()).unwrap();
        let b = 100.0;
        let mib = |elems: usize, bytes: f64| elems as f64 * bytes / (1024.0 * 1024.0);
        let w = mib(g.total_weights(), 4.0);
        assert!((w - 53.49).abs() < 0.05, "W = {w}");
        let x = mib(g.retained_act_elems(), 4.0) * b;
        assert!((x - 111.33).abs() < 0.2, "X = {x}");
        let y = mib(g.max_y_elems(), 4.0) * b;
        assert!((y - 50.0).abs() < 0.05, "Y = {y}");
        let masks = mib(g.pool_mask_elems(), 4.0) * b;
        assert!((masks - 87.46).abs() < 0.1, "masks = {masks}");
    }

    #[test]
    fn mlp_shapes() {
        let g = lower(&zoo::get("mlp").unwrap()).unwrap();
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.nodes[0].w_elems, 784 * 256);
        assert_eq!(g.nodes[4].w_elems, 256 * 10);
        assert!(g.nodes[0].first);
        assert_eq!(g.total_weights(), 784 * 256 + 3 * 256 * 256 + 256 * 10);
    }

    #[test]
    fn mini_variants_mirror_python() {
        let g = lower(&zoo::get("mlp_mini").unwrap()).unwrap();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[0].w_elems, 64 * 64);
        let g = lower(&zoo::get("cnv_mini").unwrap()).unwrap();
        assert_eq!(g.input_elems, 16 * 16 * 3);
    }

    #[test]
    fn resnet18_has_18_weight_layers() {
        let g = lower(&zoo::get("resnete18").unwrap()).unwrap();
        let convs = g.nodes.iter().filter(|n| n.is_matmul()).count();
        assert_eq!(convs, 18); // stem + 16 residual convs + fc
        let p = g.total_weights();
        assert!((11_000_000..12_000_000).contains(&p), "{p}");
    }

    #[test]
    fn bireal18_single_conv_blocks() {
        let g = lower(&zoo::get("bireal18").unwrap()).unwrap();
        let skips = g.nodes.iter().filter(|n| n.in_residual).count();
        assert_eq!(skips, 16); // every binary conv has its own skip
    }

    #[test]
    fn pooling_halves_spatial() {
        // FINN CNV has exactly two pools (28->14 and 10->5)
        let g = lower(&zoo::get("cnv").unwrap()).unwrap();
        let pools: Vec<&Node> = g
            .nodes
            .iter()
            .filter(|n| n.kind == LayerKind::MaxPool)
            .collect();
        assert_eq!(pools.len(), 2);
        assert_eq!(pools[0].out_elems * 4, pools[0].in_elems);
        // BinaryNet (same-padded) has three
        let g = lower(&zoo::get("binarynet").unwrap()).unwrap();
        assert_eq!(
            g.nodes.iter().filter(|n| n.kind == LayerKind::MaxPool).count(),
            3
        );
    }

    #[test]
    fn cnv_valid_padding_shapes() {
        // 32 -(3x3 valid)-> 30 -> 28 -pool-> 14 -> 12 -> 10 -pool-> 5
        // -> 3 -> 1; conv6 output is 1x1x256 feeding FC512
        let g = lower(&zoo::get("cnv").unwrap()).unwrap();
        let convs: Vec<&Node> =
            g.nodes.iter().filter(|n| n.kind == LayerKind::Conv).collect();
        assert_eq!(convs[0].out_elems, 30 * 30 * 64);
        assert_eq!(convs[5].out_elems, 256);
        let fc1 = g
            .nodes
            .iter()
            .find(|n| n.kind == LayerKind::Dense)
            .unwrap();
        assert_eq!(fc1.in_elems, 256);
    }

    #[test]
    fn macs_positive_and_scale() {
        let small = lower(&zoo::get("mlp_mini").unwrap()).unwrap().macs();
        let big = lower(&zoo::get("binarynet").unwrap()).unwrap().macs();
        assert!(small > 0);
        assert!(big > small * 100);
    }

    #[test]
    fn lowering_records_geometry_and_skip_markers() {
        // resnete18: stem k7/s2 SAME (224 -> 112), residual convs
        // carry open/close markers in pairs
        let g = lower(&zoo::get("resnete18").unwrap()).unwrap();
        let stem = g.nodes.iter().find(|n| n.kind == LayerKind::Conv).unwrap();
        let ng = stem.geom.unwrap();
        assert_eq!((ng.h, ng.w, ng.c_in, ng.oh, ng.ow), (224, 224, 3, 112, 112));
        assert_eq!((ng.kside, ng.stride, ng.pad), (7, 2, Padding::Same));
        assert!(!stem.skip_open && !stem.skip_close);
        let opens = g.nodes.iter().filter(|n| n.skip_open).count();
        let closes = g.nodes.iter().filter(|n| n.skip_close).count();
        assert_eq!((opens, closes), (8, 8)); // 2-conv blocks: 8 skips
        // the stage-entry conv is strided and opens its block
        let entry = g
            .nodes
            .iter()
            .find(|n| n.skip_open && n.geom.unwrap().stride == 2)
            .unwrap();
        let eg = entry.geom.unwrap();
        assert_eq!((eg.h, eg.oh), (56, 28));
        // Bi-Real: every residual conv both opens and closes
        let g = lower(&zoo::get("bireal18").unwrap()).unwrap();
        let both = g.nodes.iter().filter(|n| n.skip_open && n.skip_close).count();
        assert_eq!(both, 16);
        // VALID conv geometry (FINN CNV)
        let g = lower(&zoo::get("cnv").unwrap()).unwrap();
        let c0 = g.nodes.iter().find(|n| n.kind == LayerKind::Conv).unwrap();
        let cg = c0.geom.unwrap();
        assert_eq!((cg.h, cg.oh, cg.pad), (32, 30, Padding::Valid));
        // pool nodes record explicit output dims
        let p = g.nodes.iter().find(|n| n.kind == LayerKind::MaxPool).unwrap();
        let pg = p.geom.unwrap();
        assert_eq!((pg.h, pg.oh), (28, 14));
    }

    #[test]
    fn valid_conv_kernel_larger_than_input_rejected() {
        let spec = ModelSpec {
            name: "tiny_valid".into(),
            input_shape: vec![2, 2, 3],
            classes: 10,
            layers: vec![
                LayerSpec::conv(4, 3).valid().as_first(),
                LayerSpec::flatten(),
                LayerSpec::dense(10),
            ],
        };
        let err = lower(&spec).unwrap_err().to_string();
        assert!(err.contains("exceeds input"), "{err}");
    }

    #[test]
    fn every_zoo_model_lowers() {
        for name in names() {
            let g = lower(&zoo::get(name).unwrap()).unwrap();
            assert!(g.total_weights() > 0, "{name}");
            assert!(g.max_y_elems() > 0, "{name}");
        }
    }
}
