//! The model zoo.
//!
//! Full-scale paper models (memory modeling, Tables 2/4/5/6) and the
//! `*_mini` AOT-executable variants whose widths mirror
//! `python/compile/models.py` exactly.

use anyhow::{bail, Result};

use super::{LayerSpec as L, ModelSpec};

/// All model names, full-scale first.
pub fn names() -> &'static [&'static str] {
    &[
        "mlp",
        "cnv",
        "binarynet",
        "resnete18",
        "bireal18",
        "mlp_mini",
        "cnv_mini",
        "binarynet_mini",
        "resnete_mini",
        "bireal_mini",
    ]
}

pub fn get(name: &str) -> Result<ModelSpec> {
    Ok(match name {
        "mlp" => mlp("mlp", 784, 256, 5, 10),
        "mlp_mini" => mlp("mlp_mini", 64, 64, 3, 10),
        "cnv" => cnv_full(),
        "cnv_mini" => cnv("cnv_mini", 16, &[16, 16, 32, 32], &[64], 10),
        "binarynet" => cnv(
            "binarynet",
            32,
            &[128, 128, 256, 256, 512, 512],
            &[1024, 1024],
            10,
        ),
        "binarynet_mini" => cnv("binarynet_mini", 16, &[16, 16, 32, 32], &[64, 64], 10),
        "resnete18" => resnet18("resnete18", false),
        "bireal18" => resnet18("bireal18", true),
        "resnete_mini" => resnet_mini("resnete_mini", false),
        "bireal_mini" => resnet_mini("bireal_mini", true),
        _ => bail!("unknown model '{name}' (known: {:?})", names()),
    })
}

/// Paper's MNIST MLP: `depth` dense layers, `hidden` units each.
fn mlp(name: &str, inp: usize, hidden: usize, depth: usize, classes: usize) -> ModelSpec {
    let mut layers = Vec::new();
    for i in 0..depth - 1 {
        let mut l = L::dense(hidden);
        if i == 0 {
            l = l.as_first();
        }
        layers.push(l);
    }
    layers.push(L::dense(classes));
    ModelSpec {
        name: name.into(),
        input_shape: vec![inp],
        classes,
        layers,
    }
}

/// FINN's CNV, faithful to the original: *valid* (unpadded) 3x3
/// convs C64-C64-P-C128-C128-P-C256-C256 (no third pool; conv6's
/// output is 1x1), then FC512-FC512-FC10.  Valid padding is what
/// makes Table 4's 134.05 MiB standard-training total come out.
fn cnv_full() -> ModelSpec {
    let ch = [64usize, 64, 128, 128, 256, 256];
    let mut layers = Vec::new();
    for (i, &c) in ch.iter().enumerate() {
        let mut l = L::conv(c, 3).valid();
        if i == 0 {
            l = l.as_first();
        }
        layers.push(l);
        if i == 1 || i == 3 {
            layers.push(L::maxpool());
        }
    }
    layers.push(L::flatten());
    layers.push(L::dense(512));
    layers.push(L::dense(512));
    layers.push(L::dense(10));
    ModelSpec {
        name: "cnv".into(),
        input_shape: vec![32, 32, 3],
        classes: 10,
        layers,
    }
}

/// Courbariaux BinaryNet family (and the mini CNV variants, which
/// mirror python/compile/models.py): *same*-padded conv pairs with
/// max-pool after each pair, then an FC head.
fn cnv(name: &str, size: usize, ch: &[usize], fc: &[usize], classes: usize) -> ModelSpec {
    let mut layers = Vec::new();
    for (i, &c) in ch.iter().enumerate() {
        let mut l = L::conv(c, 3);
        if i == 0 {
            l = l.as_first();
        }
        layers.push(l);
        if i % 2 == 1 {
            layers.push(L::maxpool());
        }
    }
    layers.push(L::flatten());
    for &u in fc {
        layers.push(L::dense(u));
    }
    layers.push(L::dense(classes));
    ModelSpec {
        name: name.into(),
        input_shape: vec![size, size, 3],
        classes,
        layers,
    }
}

/// Full ImageNet-scale ResNetE-18 / Bi-Real-18: 7x7/2 stem conv +
/// max-pool, 4 stages x 2 blocks (stride-2 at stage entry), global
/// average pool, 1000-way FC.  Blocks: 2 convs/skip for ResNetE,
/// 1 conv/skip for Bi-Real — identical weight totals either way.
fn resnet18(name: &str, bireal: bool) -> ModelSpec {
    let mut layers = vec![L::conv_s(64, 7, 2).as_first(), L::maxpool()];
    let stages: &[(usize, usize)] = &[(64, 1), (128, 2), (256, 2), (512, 2)];
    for &(c, first_stride) in stages {
        if bireal {
            // Bi-Real: 4 single-conv skips per stage
            layers.push(L::residual(c, 3, first_stride, true));
            layers.push(L::residual(c, 3, 1, true));
            layers.push(L::residual(c, 3, 1, true));
            layers.push(L::residual(c, 3, 1, true));
        } else {
            // ResNetE: 2 two-conv blocks per stage
            layers.push(L::residual(c, 3, first_stride, false));
            layers.push(L::residual(c, 3, 1, false));
        }
    }
    layers.push(L::global_pool());
    layers.push(L::dense(1000));
    ModelSpec {
        name: name.into(),
        input_shape: vec![224, 224, 3],
        classes: 1000,
        layers,
    }
}

/// Mini residual nets mirroring python/compile/models.py
/// `resnet_binary(size=16, stem=16, blocks=4)`.
fn resnet_mini(name: &str, bireal: bool) -> ModelSpec {
    let mut layers = vec![L::conv(16, 3).as_first()];
    for i in 0..4usize {
        let c = if i >= 2 { 32 } else { 16 };
        layers.push(L::residual(c, 3, 1, bireal));
    }
    layers.push(L::flatten());
    layers.push(L::dense(10));
    ModelSpec {
        name: name.into(),
        input_shape: vec![16, 16, 3],
        classes: 10,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_errors() {
        assert!(get("nope").is_err());
    }

    #[test]
    fn all_names_resolve() {
        for n in names() {
            assert!(get(n).is_ok(), "{n}");
        }
    }

    #[test]
    fn resnet_weight_parity() {
        // ResNetE and Bi-Real have the same conv inventory
        let a = crate::models::lower(&get("resnete18").unwrap()).unwrap();
        let b = crate::models::lower(&get("bireal18").unwrap()).unwrap();
        assert_eq!(a.total_weights(), b.total_weights());
    }
}
