//! Memory-traffic energy model (Fig. 7c).
//!
//! The paper attributes its measured energy savings to reduced memory
//! traffic, partially offset by bool-pack/unpack work.  We model
//! exactly that mechanism: per training step,
//!
//! ```text
//! E = dram_bytes · E_DRAM + mac_ops · E_MAC + pack_ops · E_PACK
//! ```
//!
//! with constants for a Cortex-A53-class LPDDR2 system (the paper's
//! Raspberry Pi 3B+):
//!
//! - `E_DRAM`  ≈ 100 pJ/byte   (LPDDR2 access + controller; Malladi
//!   et al., ISCA'12 report 40–140 pJ/bit system-level; we take the
//!   low end ≈ 12.5 pJ/bit)
//! - `E_MAC`   ≈ 10 pJ          (32-bit multiply-accumulate @28 nm,
//!   Horowitz ISSCC'14 ≈ 3.2 pJ + pipeline overheads)
//! - `E_PACK`  ≈ 1 pJ/element   (shift+or / test+branch per bit)
//!
//! Absolute joules are indicative only; the *ratios* between standard
//! and proposed runs are the reproduction target (paper: 1.02–1.18×).

use crate::memmodel::{Dtype, DtypeConfig};
use crate::models::Graph;

pub const E_DRAM_PJ_PER_BYTE: f64 = 100.0;
pub const E_MAC_PJ: f64 = 10.0;
pub const E_PACK_PJ: f64 = 1.0;

/// Traffic + compute tally for one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCost {
    pub dram_bytes: f64,
    pub mac_ops: f64,
    pub pack_ops: f64,
}

impl StepCost {
    pub fn energy_mj(&self) -> f64 {
        (self.dram_bytes * E_DRAM_PJ_PER_BYTE
            + self.mac_ops * E_MAC_PJ
            + self.pack_ops * E_PACK_PJ)
            / 1e9
    }
}

/// Model the traffic of one training step (fwd + bwd + update).
///
/// Traffic accounting per matmul layer (batch B):
/// - fwd: read X (act dtype), read W, write Y (grad dtype), write
///   retained X̂/X (act dtype);
/// - bwd: read retained activations, read W, read/write ∂Y/∂X (grad
///   dtype), write ∂W;
/// - update: read ∂W + momenta, write W + momenta.
///
/// Pack ops: one per element binarized or bit-read (proposed only).
pub fn step_cost(graph: &Graph, batch: usize, cfg: &DtypeConfig, momenta_per_w: f64) -> StepCost {
    let b = batch as f64;
    let mut c = StepCost::default();
    for n in &graph.nodes {
        if !n.is_matmul() {
            // pooling: read input, write output + mask
            let io = (n.in_elems + n.out_elems) as f64 * b;
            c.dram_bytes += io * cfg.x.bytes() + n.in_elems as f64 * b * cfg.masks.bytes();
            continue;
        }
        let x = n.in_elems as f64 * b;
        let y = n.out_elems as f64 * b;
        let w = n.w_elems as f64;
        let (m, k, nn) = n.gemm;
        let macs = (m * k * nn) as f64 * b;

        let xbytes = if n.first { Dtype::F32.bytes() } else { cfg.x.bytes() };
        // forward
        c.dram_bytes += x * xbytes + w * cfg.w.bytes() + y * cfg.y_grads.bytes();
        c.dram_bytes += x * cfg.x.bytes(); // retain X̂ (or f32 X)
        // backward
        c.dram_bytes += x * cfg.x.bytes()
            + w * cfg.w.bytes()
            + 2.0 * y * cfg.y_grads.bytes()
            + x * cfg.y_grads.bytes()
            + w * cfg.dw.bytes();
        // update
        c.dram_bytes += w * (cfg.dw.bytes() + cfg.w.bytes())
            + 2.0 * momenta_per_w * w * cfg.momenta.bytes();

        // fwd MACs + bwd (dX and dW GEMMs) ~ 3x fwd
        c.mac_ops += 3.0 * macs;

        // pack/unpack: binarizing X and W fwd, unpacking in bwd
        if cfg.x == Dtype::Bool {
            c.pack_ops += 3.0 * x; // pack once, unpack twice (bwd ops)
        }
        if cfg.dw == Dtype::Bool {
            c.pack_ops += 2.0 * w;
        }
    }
    c
}

/// Energy ratio standard/proposed for a graph+batch (paper: ≥1, small).
pub fn ratio(graph: &Graph, batch: usize) -> f64 {
    let std = step_cost(graph, batch, &DtypeConfig::standard(), 2.0);
    let prop = step_cost(graph, batch, &DtypeConfig::proposed(), 2.0);
    std.energy_mj() / prop.energy_mj()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    #[test]
    fn proposed_uses_less_energy_but_not_dramatically() {
        // Paper Fig. 7c: 1.02x (MLP) and 1.18x (BinaryNet) — small
        // savings, eroded by pack/unpack.  Band: (1.0, 2.5).
        for m in ["mlp", "binarynet"] {
            let g = lower(&get(m).unwrap()).unwrap();
            let r = ratio(&g, 100);
            assert!(r > 1.0, "{m}: proposed must not cost more ({r})");
            assert!(r < 2.5, "{m}: saving should be modest ({r})");
        }
    }

    #[test]
    fn traffic_dominates_total() {
        let g = lower(&get("mlp").unwrap()).unwrap();
        let c = step_cost(&g, 100, &DtypeConfig::standard(), 2.0);
        let dram = c.dram_bytes * E_DRAM_PJ_PER_BYTE;
        let mac = c.mac_ops * E_MAC_PJ;
        assert!(dram > 0.0 && mac > 0.0);
    }

    #[test]
    fn pack_ops_only_for_binary_configs() {
        let g = lower(&get("mlp").unwrap()).unwrap();
        let s = step_cost(&g, 100, &DtypeConfig::standard(), 2.0);
        let p = step_cost(&g, 100, &DtypeConfig::proposed(), 2.0);
        assert_eq!(s.pack_ops, 0.0);
        assert!(p.pack_ops > 0.0);
    }

    #[test]
    fn energy_scales_with_batch() {
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let e1 = step_cost(&g, 50, &DtypeConfig::standard(), 2.0).energy_mj();
        let e2 = step_cost(&g, 100, &DtypeConfig::standard(), 2.0).energy_mj();
        assert!(e2 > e1 * 1.5, "{e1} {e2}");
    }

    #[test]
    fn conv_models_move_more_activation_traffic() {
        // BinaryNet's activation traffic dwarfs the MLP's — the
        // mechanism behind Fig. 7c's larger saving (1.18x vs 1.02x)
        let gm = lower(&get("mlp").unwrap()).unwrap();
        let gb = lower(&get("binarynet").unwrap()).unwrap();
        let pm = step_cost(&gm, 100, &DtypeConfig::proposed(), 2.0);
        let pb = step_cost(&gb, 100, &DtypeConfig::proposed(), 2.0);
        assert!(pb.pack_ops > 10.0 * pm.pack_ops);
        assert!(ratio(&gb, 100) >= 1.0 && ratio(&gm, 100) >= 1.0);
    }
}
