//! Experiment presets: every table/figure of the paper as a list of
//! runnable configurations (the benches iterate these), plus JSON
//! config-file loading for user-defined runs.

use anyhow::{anyhow, Result};

use crate::coordinator::{EngineKind, RunConfig};
use crate::util::json::Json;

/// A preset: named experiment → the runs that regenerate it.
pub struct Preset {
    pub name: &'static str,
    pub description: &'static str,
    pub runs: Vec<RunConfig>,
}

fn base(model: &str, algo: &str, opt: &str, dataset: &str, batch: usize) -> RunConfig {
    RunConfig {
        model: model.into(),
        algo: algo.into(),
        optimizer: opt.into(),
        dataset: dataset.into(),
        batch,
        epochs: 3,
        n_train: 2000,
        n_test: 400,
        eval_every_steps: 10,
        engine: EngineKind::Hlo,
        ..Default::default()
    }
}

/// Dataset each benchmark model trains on (mini surrogates).
pub fn dataset_for(model: &str) -> &'static str {
    match model {
        "mlp" => "syn-mnist",
        "mlp_mini" => "syn-mnist64",
        "cnv_mini" | "binarynet_mini" => "syn-cifar16",
        "resnete_mini" | "bireal_mini" => "syn-imagenet16",
        _ => "syn-cifar16",
    }
}

pub fn preset(name: &str) -> Result<Preset> {
    Ok(match name {
        // Table 3/4: std vs proposed per model/dataset pair
        "table34" => Preset {
            name: "table34",
            description: "Tables 3-4: accuracy std vs proposed across models",
            runs: {
                let mut v = Vec::new();
                for (model, ds) in [
                    ("mlp_mini", "syn-mnist64"),
                    ("cnv_mini", "syn-cifar16"),
                    ("cnv_mini", "syn-svhn16"),
                    ("binarynet_mini", "syn-cifar16"),
                    ("binarynet_mini", "syn-svhn16"),
                ] {
                    for algo in ["standard", "proposed"] {
                        let batch = if model == "mlp_mini" { 64 } else { 100 };
                        let mut c = base(model, algo, "adam", ds, batch);
                        c.epochs = 4;
                        v.push(c);
                    }
                }
                v
            },
        },
        // Table 5: ablation x optimizer on BinaryNet-mini
        "table5" => Preset {
            name: "table5",
            description: "Table 5: data-representation ablation x optimizer",
            runs: {
                let mut v = Vec::new();
                for opt in ["adam", "sgd", "bop"] {
                    for algo in
                        ["standard", "f16", "boolgrad_l2", "boolgrad_l1", "proposed"]
                    {
                        let mut c =
                            base("binarynet_mini", algo, opt, "syn-cifar16", 100);
                        c.lr = if opt == "sgd" { 0.1 } else { 0.001 };
                        c.epochs = 3;
                        v.push(c);
                    }
                }
                v
            },
        },
        // Table 6: residual minis, per-approximation
        "table6" => Preset {
            name: "table6",
            description: "Table 6: ResNetE/Bi-Real per-approximation accuracy",
            runs: {
                let mut v = Vec::new();
                for model in ["resnete_mini", "bireal_mini"] {
                    for algo in
                        ["standard", "f16", "boolgrad_l2", "boolgrad_l1", "proposed"]
                    {
                        let mut c = base(model, algo, "adam", "syn-imagenet16", 64);
                        c.epochs = 3;
                        v.push(c);
                    }
                }
                v
            },
        },
        // Fig 2: batch sweep
        "fig2" => Preset {
            name: "fig2",
            description: "Fig. 2: batch size vs accuracy/memory per optimizer",
            runs: {
                let mut v = Vec::new();
                for opt in ["adam", "sgd", "bop"] {
                    for algo in ["standard", "proposed"] {
                        for b in [16usize, 64, 256] {
                            let mut c =
                                base("binarynet_mini", algo, opt, "syn-cifar16", b);
                            c.lr = if opt == "sgd" { 0.1 } else { 0.001 };
                            c.epochs = 2;
                            v.push(c);
                        }
                    }
                }
                v
            },
        },
        _ => return Err(anyhow!("unknown preset '{name}'")),
    })
}

/// Parse a user config file: `{"runs": [{...RunConfig fields...}]}`.
pub fn from_json(text: &str) -> Result<Vec<RunConfig>> {
    let j = Json::parse(text)?;
    let runs = j.req("runs")?.as_arr()?;
    runs.iter().map(run_from_json).collect()
}

fn run_from_json(j: &Json) -> Result<RunConfig> {
    let d = RunConfig::default();
    let gs = |k: &str, dv: &str| -> String {
        j.get(k).and_then(|v| v.as_str().ok()).unwrap_or(dv).to_string()
    };
    let gu = |k: &str, dv: usize| -> usize {
        j.get(k).and_then(|v| v.as_usize().ok()).unwrap_or(dv)
    };
    let gf = |k: &str, dv: f64| -> f64 {
        j.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(dv)
    };
    Ok(RunConfig {
        model: gs("model", &d.model),
        algo: gs("algo", &d.algo),
        optimizer: gs("optimizer", &d.optimizer),
        dataset: gs("dataset", &d.dataset),
        batch: gu("batch", d.batch),
        epochs: gu("epochs", d.epochs),
        lr: gf("lr", d.lr as f64) as f32,
        engine: EngineKind::parse(&gs("engine", "hlo"))?,
        threads: gu("threads", d.threads),
        microbatch: gu("microbatch", d.microbatch),
        seed: gu("seed", d.seed as usize) as u64,
        n_train: gu("n_train", d.n_train),
        n_test: gu("n_test", d.n_test),
        eval_every_steps: gu("eval_every", d.eval_every_steps),
        ..Default::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build() {
        assert_eq!(preset("table34").unwrap().runs.len(), 10);
        assert_eq!(preset("table5").unwrap().runs.len(), 15);
        assert_eq!(preset("table6").unwrap().runs.len(), 10);
        assert_eq!(preset("fig2").unwrap().runs.len(), 18);
        assert!(preset("nope").is_err());
    }

    #[test]
    fn preset_configs_are_consistent() {
        for p in ["table34", "table5", "table6", "fig2"] {
            for run in preset(p).unwrap().runs {
                // model exists + dataset matches its input size
                let g = crate::models::lower(&crate::models::get(&run.model).unwrap())
                    .unwrap();
                let ds = crate::data::build(&run.dataset, 4, 0, 1).unwrap();
                assert_eq!(ds.sample_elems(), g.input_elems, "{p}/{}", run.model);
            }
        }
    }

    #[test]
    fn json_config_roundtrip() {
        let cfgs = from_json(
            r#"{"runs": [{"model": "cnv_mini", "dataset": "syn-cifar16",
                 "batch": 32, "lr": 0.01, "engine": "blocked"}]}"#,
        )
        .unwrap();
        assert_eq!(cfgs.len(), 1);
        assert_eq!(cfgs[0].model, "cnv_mini");
        assert_eq!(cfgs[0].batch, 32);
        assert_eq!(cfgs[0].engine, EngineKind::Blocked);
        assert!((cfgs[0].lr - 0.01).abs() < 1e-9);
    }

    #[test]
    fn json_config_tiled_engine_with_threads() {
        let cfgs = from_json(
            r#"{"runs": [{"model": "mlp_mini", "dataset": "syn-mnist64",
                 "engine": "tiled", "threads": 4, "microbatch": 16}]}"#,
        )
        .unwrap();
        assert_eq!(cfgs[0].engine, EngineKind::Tiled);
        assert_eq!(cfgs[0].threads, 4);
        assert_eq!(cfgs[0].microbatch, 16);
        // threads / microbatch default to 0 (auto / whole batch)
        let d = from_json(r#"{"runs": [{"engine": "tiled"}]}"#).unwrap();
        assert_eq!(d[0].threads, 0);
        assert_eq!(d[0].microbatch, 0);
    }

    #[test]
    fn bad_json_errors() {
        assert!(from_json("{}").is_err());
        assert!(from_json(r#"{"runs": [{"engine": "gpu"}]}"#).is_err());
    }
}
