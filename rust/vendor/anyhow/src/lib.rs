//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image cannot reach crates.io, so this shim provides the
//! subset of anyhow's surface the codebase uses — `Error`, `Result`,
//! `anyhow!`, `bail!`, and the `Context` extension trait — with the
//! same semantics for that subset: any `std::error::Error` converts
//! into `Error` via `?`, and `.context(..)` / `.with_context(..)`
//! prepend a message (source messages are flattened into one string
//! rather than kept as a chain).

use std::fmt;

/// String-backed error value.  Like anyhow's, it deliberately does
/// NOT implement `std::error::Error` — that is what makes the blanket
/// `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's
    /// backing constructor).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    // `fn main() -> anyhow::Result<()>` prints via Debug; show the
    // message, not a struct dump.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// One impl covers both `Result<T, io::Error>`-style results (via the
// blanket From above) and `Result<T, Error>` (via the reflexive
// `From<T> for T`), so no overlapping-impl tricks are needed.
impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{c}: {e}") }
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{}: {e}", f()) }
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — format a new [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(format!($($t)*))
    };
}

/// `bail!("...")` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let msg = format!("{}", r.unwrap_err());
        assert!(msg.starts_with("reading manifest: "), "{msg}");
        assert!(msg.contains("boom"));
    }

    #[test]
    fn option_context_and_with_context() {
        let r: Result<i32> = None.context("missing");
        assert_eq!(format!("{}", r.unwrap_err()), "missing");
        let r: Result<i32> = Some(3).with_context(|| "unused");
        assert_eq!(r.unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn inner() -> Result<()> {
            bail!("nope {}", "x");
        }
        assert_eq!(inner().unwrap_err().to_string(), "nope x");
    }

    #[test]
    fn question_mark_chains() {
        fn io() -> Result<(), std::io::Error> {
            Err(io_err())
        }
        fn outer() -> Result<()> {
            io()?;
            Ok(())
        }
        assert!(outer().is_err());
    }
}
