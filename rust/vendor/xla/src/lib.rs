//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The build image has neither the `xla_extension` C++ toolchain nor
//! network access, so this crate mirrors the API surface
//! `bnn_edge::runtime` uses and fails at *runtime*, not compile time:
//! `PjRtClient::cpu()` (and every other entry point) returns an error
//! explaining that PJRT is unavailable.  The coordinator's
//! `EngineKind::Hlo` path therefore degrades into a clear error
//! message while the pure-Rust engines (`naive`/`blocked`/`tiled`)
//! stay fully functional.  Swapping in the real bindings is a
//! one-line Cargo change; no `bnn_edge` source changes.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT/XLA is unavailable in this build (offline `xla` stub — \
         install the xla_extension toolchain and point Cargo at the real \
         bindings to enable the HLO engine)"
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let lit = Literal::vec1(&[1.0]);
        assert!(lit.reshape(&[1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("unavailable"), "{msg}");
    }
}
