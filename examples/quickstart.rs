//! Quickstart: load an AOT train-step artifact, run a few steps on a
//! synthetic batch, and print the Table-2 memory story for the same
//! configuration.
//!
//!     make artifacts            # once (python, build-time only)
//!     cargo run --release --example quickstart
//!
//! Everything below is pure Rust — the PJRT executable was compiled
//! from JAX+Pallas ahead of time; Python is not on this path.

use anyhow::Result;
use bnn_edge::coordinator::{EngineKind, RunConfig, Runner};
use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::report;

fn main() -> Result<()> {
    // 1. The memory claim (Sec. 4 / Table 2): why the proposed
    //    training step fits edge devices.
    let graph = lower(&get("mlp_mini")?)?;
    let std = breakdown(&graph, 64, &DtypeConfig::standard(), Optimizer::Adam);
    let prop = breakdown(&graph, 64, &DtypeConfig::proposed(), Optimizer::Adam);
    println!("{}", report::table2(&std, &prop));

    // 2. Train the same model for real through the AOT HLO step
    //    (Alg. 2 baked in by python/compile at build time).
    let cfg = RunConfig {
        model: "mlp_mini".into(),
        algo: "proposed".into(),
        dataset: "syn-mnist64".into(),
        batch: 64,
        epochs: 2,
        n_train: 640,
        n_test: 128,
        eval_every_steps: 5,
        lr: 0.003,
        engine: EngineKind::Hlo,
        ..Default::default()
    };
    println!("training {} ({})...", cfg.model, cfg.train_artifact());
    let mut runner = Runner::new(cfg)?;
    let result = runner.run()?;
    println!("{}", result.summary());

    // 3. Show the loss trend (the metrics stream drives Figs. 3-5).
    let pts = &result.metrics.points;
    let first = pts.first().unwrap();
    let last = pts.iter().rev().find(|p| p.val_acc.is_some()).unwrap();
    println!(
        "loss {:.3} -> {:.3}; val acc {:.1}% at step {}",
        first.train_loss,
        last.train_loss,
        last.val_acc.unwrap() * 100.0,
        last.step
    );
    Ok(())
}
