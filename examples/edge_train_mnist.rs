//! End-to-end edge-training driver — the repository's E2E validation
//! run (recorded in EXPERIMENTS.md §E2E).
//!
//! Trains the paper's MNIST MLP (784-256-256-256-256-10, the actual
//! paper-scale model) for several hundred steps on the synthetic
//! MNIST surrogate through the **full three-layer stack**:
//!
//!   L1 Pallas kernels → L2 JAX train step → AOT HLO text →
//!   L3 Rust PJRT runtime → this coordinator loop,
//!
//! under a Raspberry-Pi-class memory envelope, logging the loss curve
//! and both algorithms' (standard vs proposed) accuracy + modeled
//! memory side by side.
//!
//!     cargo run --release --example edge_train_mnist [-- --steps 300]

use anyhow::Result;
use bnn_edge::coordinator::{EngineKind, MemoryEnvelope, RunConfig, Runner};
use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::report::{acc_table, AccRow};
use bnn_edge::util::cli::Args;
use bnn_edge::util::MIB;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 300)?;
    let use_pallas = !args.bool("no-pallas");

    let graph = lower(&get("mlp")?)?;
    let mut rows = Vec::new();
    let mut baseline = 0.0f32;

    for algo in ["standard", "proposed"] {
        let cfg = RunConfig {
            model: "mlp".into(),
            algo: algo.into(),
            dataset: "syn-mnist".into(),
            batch: 100,
            epochs: 100, // bounded by max_steps
            max_steps: Some(steps),
            n_train: 4000,
            n_test: 1000,
            eval_every_steps: 20,
            lr: 0.001,
            engine: EngineKind::Hlo,
            envelope: Some(MemoryEnvelope::raspberry_pi()),
            metrics_path: Some(format!("results/e2e_mlp_{algo}.jsonl").into()),
            // route the proposed run through the Pallas-kernel artifact
            use_pallas_artifact: use_pallas && algo == "proposed",
            ..Default::default()
        };
        println!("== {algo}: artifact {} ==", cfg.train_artifact());
        let mut runner = Runner::new(cfg)?;
        let result = runner.run()?;
        println!("{}", result.summary());
        // print the loss curve coarsely (full curve in the jsonl)
        for p in result.metrics.points.iter().step_by(40) {
            println!(
                "  step {:>4}  loss {:.4}  train acc {:.1}%{}",
                p.step,
                p.train_loss,
                p.train_acc * 100.0,
                p.val_acc
                    .map(|v| format!("  val acc {:.1}%", v * 100.0))
                    .unwrap_or_default()
            );
        }
        let dt = DtypeConfig::ablation(algo).unwrap();
        let mib = breakdown(&graph, 100, &dt, Optimizer::Adam).total_bytes() / MIB;
        if algo == "standard" {
            baseline = result.best_test_acc;
        }
        let std_mib =
            breakdown(&graph, 100, &DtypeConfig::standard(), Optimizer::Adam).total_bytes()
                / MIB;
        rows.push(AccRow {
            label: format!("MLP/syn-MNIST {algo}"),
            baseline_acc: baseline,
            acc: result.best_test_acc,
            mib: Some(mib),
            mib_factor: if algo == "proposed" {
                Some(std_mib / mib)
            } else {
                None
            },
        });
    }

    let md = acc_table(
        "E2E: MLP (paper scale) on syn-MNIST — standard vs proposed",
        &rows,
    );
    println!("{md}");
    bnn_edge::report::write_section("results/e2e_mlp.md", &md)?;
    println!("curves: results/e2e_mlp_standard.jsonl / _proposed.jsonl");
    Ok(())
}
