//! Memory-envelope exploration: what actually fits on an edge device?
//!
//! Walks the model zoo under a Raspberry-Pi-class envelope, printing
//! for each model/algorithm the largest admissible batch (Fig. 2's
//! "~10× batch" observation) and for BinaryNet the full Table-2
//! breakdown at that operating point.  Also runs the tracked-
//! allocator measurement for the naive engines so *measured* peak
//! memory can be compared with the model (Fig. 6's methodology).
//!
//!     cargo run --release --example memory_envelope [-- --envelope-mib 819]

use anyhow::Result;
use bnn_edge::coordinator::{fit_batch, MemoryEnvelope};
use bnn_edge::data::build;
use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::schedule::{self, PoolKind};
use bnn_edge::naive::{build_engine, Accel, Plan};
use bnn_edge::util::cli::Args;
use bnn_edge::util::table::{Align, Table};
use bnn_edge::util::MIB;
use bnn_edge::{memtrack, report};

#[global_allocator]
static ALLOC: memtrack::TrackingAlloc = memtrack::TrackingAlloc;

fn main() -> Result<()> {
    let args = Args::from_env();
    let env = MemoryEnvelope::mib(args.f64_or("envelope-mib", 819.0)?);

    let mut t = Table::new(
        &format!("Largest batch within {:.0} MiB", env.bytes / MIB),
        &["Model", "standard", "proposed", "headroom"],
    )
    .align(0, Align::Left);
    for model in ["mlp", "cnv", "binarynet", "resnete18"] {
        let g = lower(&get(model)?)?;
        let s = fit_batch(&g, "standard", Optimizer::Adam, &env)?;
        let p = fit_batch(&g, "proposed", Optimizer::Adam, &env)?;
        let ratio = match (s, p) {
            (Some(a), Some(b)) if a > 0 => format!("{:.1}x", b as f64 / a as f64),
            _ => "-".into(),
        };
        let fmt = |x: Option<usize>| x.map(|v| v.to_string()).unwrap_or("-".into());
        t.row(&[model.to_string(), fmt(s), fmt(p), ratio]);
    }
    println!("{}", t.to_markdown());

    // Table-2 breakdown at the paper's BinaryNet operating point.
    let g = lower(&get("binarynet")?)?;
    let std = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam);
    let prop = breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Adam);
    println!("{}", report::table2(&std, &prop));

    // Measured (tracking allocator) vs modeled, naive engines on the
    // paper's MLP — the Fig. 6 methodology in miniature.  Since the
    // step-arena work the interesting split is *first step* (the
    // warmup that populates the arena pool) vs *steady state* (every
    // later step: zero heap allocations, peak growth ~0 because all
    // buffers come from the resident pool).
    let g = lower(&get("mlp")?)?;
    let plan = Plan::from_graph(&g)?;
    let batch = 100;
    let ds = build("syn-mnist", batch, 0, 1)?;
    let x = ds.train_x.clone();
    let y = ds.train_y.clone();
    println!("measured heap while training (MLP, B={batch}, blocked backend):");
    for algo in ["standard", "proposed"] {
        let mut engine = build_engine(algo, &g, batch, "adam", Accel::Blocked, 1)?;
        let (_, first) = memtrack::measure(|| engine.train_step(&x, &y, 0.001));
        let (_, steady) = memtrack::measure(|| engine.train_step(&x, &y, 0.001));
        let dt = DtypeConfig::ablation(algo).unwrap();
        let modeled = breakdown(&g, batch, &dt, Optimizer::Adam).total_bytes() / MIB;
        let state = engine.state_bytes() as f64 / MIB;
        let arena = engine.arena_bytes() as f64 / MIB;
        println!(
            "  {algo:>9}: first step peak-growth {:.2} MiB / {} allocs -> steady step \
             peak-growth {:.2} MiB / {} allocs",
            first.growth_mib(),
            first.allocs,
            steady.growth_mib(),
            steady.allocs
        );
        println!(
            "             resident: state {state:.2} MiB + step arena {arena:.2} MiB  \
             (paper-modeled step total {modeled:.2} MiB)"
        );
        // the compiled slot map behind that arena number: typed
        // pools, interval-colored so disjoint live ranges share slots
        let sched = schedule::compile_step(&plan, algo, false, batch, 1)?;
        let saved = sched.uncolored_bytes.saturating_sub(sched.arena_bytes());
        let pools: Vec<String> = PoolKind::ALL
            .iter()
            .filter(|&&p| sched.slots.pool_bytes(p) > 0)
            .map(|&p| format!("{} {:.2} MiB", p.name(), sched.slots.pool_bytes(p) as f64 / MIB))
            .collect();
        println!(
            "             schedule: {} slots [{}]  coloring saves {:.2} MiB vs \
             best-fit ({:.1}%)",
            sched.slot_count(),
            pools.join(", "),
            saved as f64 / MIB,
            100.0 * saved as f64 / sched.uncolored_bytes.max(1) as f64
        );
        // the planned envelope (state + scheduled arena), per microbatch
        for micro in [0usize, batch / 4] {
            let env = bnn_edge::memmodel::step_envelope(
                &g,
                algo,
                Optimizer::Adam,
                batch,
                micro,
            )?;
            println!(
                "             step_envelope(micro={:>3}): {:.2} MiB",
                if micro == 0 { batch } else { micro },
                env.total_mib()
            );
        }
    }
    Ok(())
}
