//! Federated edge-fleet demo: the paper's federated-learning
//! motivation (Sec. 1) made concrete.
//!
//! A leader coordinates N simulated edge devices (threads).  Each
//! device trains the proposed low-memory step (Alg. 2) on its private
//! shard and uplinks a **1-bit-per-weight sign update** — the
//! communication-side twin of the paper's binary weight gradients.
//! The leader majority-votes the signs (cf. signSGD, the paper's
//! ref [9]) and broadcasts the new weights.
//!
//! The fleet is fault-tolerant: pass `--chaos hostile` to inject
//! seeded crash/stall/drop/corrupt faults and watch rounds commit
//! anyway (staleness-discounted votes, quorum, straggler backoff).
//!
//!     cargo run --release --example federated_edge [-- --workers 6 --rounds 8 --chaos hostile]

use anyhow::Result;
use bnn_edge::federated::{AsyncConfig, FaultPlan, FedConfig, Leader};
use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::util::cli::Args;
use bnn_edge::util::MIB;

fn main() -> Result<()> {
    let args = Args::from_env();
    let workers = args.usize_or("workers", 4)?;
    let mut cfg = FedConfig::fleet(workers);
    cfg.rounds = args.usize_or("rounds", 8)?;
    cfg.local_steps = args.usize_or("local-steps", 10)?;
    cfg.batch = args.usize_or("batch", 32)?;
    cfg.model = args.str_or("model", "mlp_mini");
    cfg.dataset = args.str_or("dataset", "syn-mnist64");
    cfg.lr = args.f64_or("lr", 0.003)? as f32;
    cfg.fed_lr = args.f64_or("fed-lr", 0.02)? as f32;
    cfg.seed = args.usize_or("seed", 42)? as u64;
    cfg.samples_per_worker = args.usize_or("samples-per-worker", 320)?;
    cfg.async_cfg = AsyncConfig::majority(workers);
    cfg.async_cfg.deadline_ms = args.usize_or("deadline-ms", 2000)? as u64;
    cfg.plan = FaultPlan::parse(&args.str_or("chaos", "none"), cfg.seed)?;

    // Per-device memory: each worker runs the proposed step, so its
    // on-device footprint is the Table-2 proposed column.
    let graph = lower(&get(&cfg.model)?)?;
    let dev =
        breakdown(&graph, cfg.batch, &DtypeConfig::proposed(), Optimizer::Adam);
    println!(
        "fleet: {} devices x {:.2} MiB modeled on-device training memory",
        cfg.workers,
        dev.total_bytes() / MIB
    );

    let mut leader = Leader::new(cfg)?;
    let result = leader.run()?;
    for s in &result.round_stats {
        println!(
            "round {}: {} admitted={} (fresh {} stale {}) loss {:.4}",
            s.round,
            if s.committed { "commit" } else { "stall " },
            s.admitted,
            s.fresh,
            s.stale,
            s.mean_loss
        );
    }
    println!("{}", result.summary());
    Ok(())
}
