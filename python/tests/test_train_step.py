"""Train-step assembly: optimizer updates, learning dynamics per
(model, algo, optimizer) variant, flat-wrapper I/O contract, and
hypothesis sweeps over batch/width."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as L
from compile import models as M
from compile import train_step as T

jax.config.update("jax_platform_name", "cpu")


def toy_data(key, spec, n):
    k1, k2, k3 = jax.random.split(key, 3)
    protos = jax.random.normal(k1, (spec.classes,) + spec.input_shape)
    lbl = jax.random.randint(k2, (n,), 0, spec.classes)
    x = protos[lbl] + 0.4 * jax.random.normal(k3, (n,) + spec.input_shape)
    return x, jax.nn.one_hot(lbl, spec.classes), lbl


def train_n(spec, cfg, optimizer, steps, batch=64, lr=0.003, seed=0):
    key = jax.random.PRNGKey(seed)
    params = M.init_params(spec, key)
    if optimizer == "bop":
        params = T.init_bop_weights(params)
    opt = T.init_opt_state(spec, optimizer)
    step = jax.jit(T.make_train_step(spec, cfg, optimizer))
    x, y, _ = toy_data(key, spec, batch)
    losses = []
    for _ in range(steps):
        params, opt, loss, acc = step(params, opt, x, y, jnp.float32(lr))
        losses.append(float(loss))
    return params, losses, float(acc)


class TestLearningDynamics:
    @pytest.mark.parametrize("optimizer", ["adam", "sgd", "bop"])
    def test_mlp_learns_with_each_optimizer(self, optimizer):
        spec = M.mlp_mini()
        cfg = L.TrainConfig.proposed()
        lr = {"adam": 0.003, "sgd": 0.05, "bop": 0.001}[optimizer]
        _, losses, _ = train_n(spec, cfg, optimizer, 40, lr=lr)
        assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])

    @pytest.mark.parametrize(
        "algo", ["standard", "f16", "boolgrad_l2", "boolgrad_l1",
                 "proposed", "nn_standard", "nn_proposed"]
    )
    def test_every_ablation_learns(self, algo):
        spec = M.mlp_mini()
        cfg = L.TrainConfig.ablation(algo)
        _, losses, _ = train_n(spec, cfg, "adam", 40)
        assert losses[-1] < losses[0] * 0.85, (algo, losses[0], losses[-1])

    def test_weights_stay_clipped(self):
        spec = M.mlp_mini()
        cfg = L.TrainConfig.proposed()
        params, _, _ = train_n(spec, cfg, "adam", 20, lr=0.1)
        for i in range(0, len(params), 2):
            assert float(jnp.max(jnp.abs(params[i]))) <= 1.0

    def test_bop_weights_stay_binary(self):
        spec = M.mlp_mini()
        cfg = L.TrainConfig.proposed()
        params, _, _ = train_n(spec, cfg, "bop", 15)
        for i in range(0, len(params), 2):
            vals = set(np.unique(np.asarray(params[i])))
            assert vals <= {-1.0, 1.0}, vals


class TestOptStateLayout:
    def test_adam_state_size(self):
        spec = M.mlp_mini()
        shapes = T.opt_state_shapes(spec, "adam")
        nparams = 2 * spec.num_param_layers()
        assert len(shapes) == 1 + 2 * nparams
        assert shapes[0] == ()

    def test_sgd_state_size(self):
        spec = M.mlp_mini()
        assert len(T.opt_state_shapes(spec, "sgd")) == 2 * spec.num_param_layers()

    def test_bop_state_size(self):
        spec = M.mlp_mini()
        n = spec.num_param_layers()
        assert len(T.opt_state_shapes(spec, "bop")) == n + 1 + 2 * n

    def test_unknown_optimizer(self):
        with pytest.raises(ValueError):
            T.opt_state_shapes(M.mlp_mini(), "rmsprop")


class TestFlatWrappers:
    def test_flat_train_roundtrip(self):
        spec = M.mlp_mini()
        cfg = L.TrainConfig.proposed()
        flat, nparams, nopt = T.make_flat_train_step(spec, cfg, "adam")
        key = jax.random.PRNGKey(0)
        params = M.init_params(spec, key)
        opt = T.init_opt_state(spec, "adam")
        x, y, _ = toy_data(key, spec, 16)
        outs = flat(*params, *opt, x, y, jnp.float32(0.001))
        assert len(outs) == nparams + nopt + 2
        # output shapes mirror input shapes positionally
        for got, want in zip(outs, params + opt):
            assert got.shape == want.shape

    def test_flat_eval(self):
        spec = M.mlp_mini()
        cfg = L.TrainConfig.proposed()
        flat, nparams = T.make_flat_eval_step(spec, cfg)
        key = jax.random.PRNGKey(0)
        params = M.init_params(spec, key)
        x, y, _ = toy_data(key, spec, 16)
        loss, acc = flat(*params, x, y)
        assert loss.shape == () and acc.shape == ()
        assert 0.0 <= float(acc) <= 1.0

    def test_eval_is_pure(self):
        spec = M.mlp_mini()
        cfg = L.TrainConfig.proposed()
        flat, _ = T.make_flat_eval_step(spec, cfg)
        key = jax.random.PRNGKey(1)
        params = M.init_params(spec, key)
        x, y, _ = toy_data(key, spec, 16)
        a = flat(*params, x, y)
        b = flat(*params, x, y)
        assert float(a[0]) == float(b[0])


@given(
    batch=st.sampled_from([1, 2, 8, 32]),
    hidden=st.sampled_from([16, 48, 64]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=8, deadline=None)
def test_step_runs_across_shapes(batch, hidden, seed):
    """Hypothesis sweep: the full proposed step traces and runs for
    arbitrary batch/width combinations with finite outputs."""
    spec = M.mlp(name="t", inp=32, hidden=hidden, depth=3, classes=5)
    cfg = L.TrainConfig.proposed()
    key = jax.random.PRNGKey(seed)
    params = M.init_params(spec, key)
    opt = T.init_opt_state(spec, "adam")
    step = T.make_train_step(spec, cfg, "adam")
    x, y, _ = toy_data(key, spec, batch)
    params2, opt2, loss, acc = step(params, opt, x, y, jnp.float32(0.001))
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0
    for p in params2:
        assert bool(jnp.all(jnp.isfinite(p)))


class TestModelZoo:
    @pytest.mark.parametrize("name", list(M.ZOO.keys()))
    def test_init_and_forward(self, name):
        spec = M.get_model(name)
        key = jax.random.PRNGKey(0)
        params = M.init_params(spec, key)
        assert len(params) == 2 * spec.num_param_layers()
        x = jax.random.normal(key, (2,) + spec.input_shape)
        logits = M.apply_model(spec, L.TrainConfig.proposed(), params, x)
        assert logits.shape == (2, spec.classes)

    def test_resnete_vs_bireal_param_counts(self):
        a = M.get_model("resnete_mini")
        b = M.get_model("bireal_mini")
        # ResNetE has 2 convs per skip: more param layers
        assert a.num_param_layers() > b.num_param_layers()

    def test_glorot_scale(self):
        spec = M.mlp_mini()
        params = M.init_params(spec, jax.random.PRNGKey(0))
        w0 = np.asarray(params[0])
        limit = np.sqrt(6.0 / (64 + 64))
        assert np.abs(w0).max() <= limit + 1e-6
        assert np.abs(w0).std() > 0.01
