"""Kernel vs. ref oracle — the CORE correctness signal for L1.

Every Pallas kernel must match its pure-jnp oracle to float32
tolerance across a hypothesis-swept space of shapes and value
distributions, including the degenerate corners (single-row batches,
single channels, non-tile-multiple dims, all-negative inputs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_matmul import (
    binary_matmul,
    mxu_utilization_estimate,
    vmem_bytes as bm_vmem,
)
from compile.kernels.l1_batchnorm import l1_batchnorm_fwd
from compile.kernels.bn_backward import bn_backward_proposed
from compile.kernels.sign import sign_ste

jax.config.update("jax_platform_name", "cpu")

def rng(seed):
    return np.random.default_rng(seed)


# --------------------------------------------------------------- sign

def test_sign_zero_is_plus_one():
    s = ref.sign(jnp.array([0.0, -0.0, 1.0, -1.0]))
    # sgn(0) = +1: codomain must be exactly {-1, +1}
    assert s.tolist() == [1.0, 1.0, 1.0, -1.0]


def test_sign_codomain_binary():
    x = jnp.asarray(rng(0).normal(size=(64, 32)), jnp.float32)
    s = ref.sign(x)
    assert set(np.unique(np.asarray(s))) <= {-1.0, 1.0}


@given(
    r=st.integers(1, 70),
    c=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_sign_ste_matches_ref(r, c, seed):
    x = jnp.asarray(rng(seed).normal(size=(r, c)) * 2, jnp.float32)
    s, m = sign_ste(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref.sign(x)))
    np.testing.assert_allclose(np.asarray(m), np.asarray(ref.ste_mask(x)))


def test_ste_mask_boundary_inclusive():
    x = jnp.array([[1.0, -1.0, 1.0001, -1.0001]])
    _, m = sign_ste(x)
    assert m.tolist() == [[1.0, 1.0, 0.0, 0.0]]


# ------------------------------------------------------ binary matmul

@given(
    m=st.integers(1, 65),
    k=st.integers(1, 65),
    n=st.integers(1, 65),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_binary_matmul_matches_ref(m, k, n, seed):
    g = rng(seed)
    x = jnp.asarray(g.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(g.normal(size=(k, n)), jnp.float32)
    got = binary_matmul(x, w)
    want = ref.binary_matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_binary_matmul_large_tile_multiple():
    g = rng(7)
    x = jnp.asarray(g.normal(size=(256, 256)), jnp.float32)
    w = jnp.asarray(g.normal(size=(256, 128)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(binary_matmul(x, w)),
        np.asarray(ref.binary_matmul(x, w)),
        atol=1e-4,
    )


def test_binary_matmul_output_parity():
    # sum of K +/-1 products has the same parity as K
    g = rng(1)
    k = 33
    x = jnp.asarray(g.normal(size=(8, k)), jnp.float32)
    w = jnp.asarray(g.normal(size=(k, 8)), jnp.float32)
    out = np.asarray(binary_matmul(x, w))
    assert np.all((out.astype(np.int64) - k) % 2 == 0)
    assert np.all(np.abs(out) <= k)


def test_binary_matmul_all_positive_inputs():
    x = jnp.ones((4, 16))
    w = jnp.ones((16, 4))
    np.testing.assert_allclose(np.asarray(binary_matmul(x, w)), 16.0)


def test_binary_matmul_ignores_magnitude():
    g = rng(3)
    x = jnp.asarray(g.normal(size=(16, 32)), jnp.float32)
    w = jnp.asarray(g.normal(size=(32, 16)), jnp.float32)
    a = binary_matmul(x, w)
    b = binary_matmul(x * 100.0, w * 0.001)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_mxu_utilization_estimate_exact_tiles():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(64, 128, 128) < 1.0


def test_vmem_budget():
    # default tiling must stay far below the 16 MiB VMEM budget
    assert bm_vmem() < 4 * 2**20


# ---------------------------------------------------------- l1 BN fwd

@given(
    b=st.integers(2, 64),
    c=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_l1_bn_fwd_matches_ref(b, c, seed):
    g = rng(seed)
    y = jnp.asarray(g.normal(size=(b, c)) * 3, jnp.float32)
    beta = jnp.asarray(g.normal(size=(c,)) * 0.1, jnp.float32)
    x, mu, psi, om = l1_batchnorm_fwd(y, beta)
    xr, mur, psir, omr = ref.batchnorm_l1_fwd(y, beta)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mur), atol=1e-6)
    np.testing.assert_allclose(np.asarray(psi), np.asarray(psir), atol=1e-6)
    np.testing.assert_allclose(np.asarray(om), np.asarray(omr), atol=1e-5, rtol=1e-5)


def test_l1_bn_fwd_centering():
    # output (minus beta) must have ~zero batch mean per channel
    g = rng(11)
    y = jnp.asarray(g.normal(size=(128, 16)) * 5 + 2, jnp.float32)
    beta = jnp.zeros((16,))
    x, _, _, _ = l1_batchnorm_fwd(y, beta)
    np.testing.assert_allclose(np.asarray(jnp.mean(x, 0)), 0.0, atol=1e-4)


def test_l1_bn_fwd_scale_invariant_shape():
    # psi is the mean absolute deviation: scaling y scales psi
    g = rng(12)
    y = jnp.asarray(g.normal(size=(64, 8)), jnp.float32)
    beta = jnp.zeros((8,))
    _, _, psi1, _ = l1_batchnorm_fwd(y, beta)
    _, _, psi2, _ = l1_batchnorm_fwd(y * 10.0, beta)
    np.testing.assert_allclose(np.asarray(psi2), np.asarray(psi1) * 10, rtol=1e-3)


def test_l1_bn_fwd_beta_shifts_output():
    g = rng(13)
    y = jnp.asarray(g.normal(size=(32, 4)), jnp.float32)
    x0, _, _, _ = l1_batchnorm_fwd(y, jnp.zeros((4,)))
    x1, _, _, _ = l1_batchnorm_fwd(y, jnp.full((4,), 0.5))
    np.testing.assert_allclose(np.asarray(x1 - x0), 0.5, atol=1e-5)


# --------------------------------------------------- proposed BN bwd

@given(
    b=st.integers(2, 64),
    c=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_bn_backward_proposed_matches_ref(b, c, seed):
    g = rng(seed)
    dx = jnp.asarray(g.normal(size=(b, c)), jnp.float32)
    xhat = ref.sign(jnp.asarray(g.normal(size=(b, c)), jnp.float32))
    omega = jnp.asarray(np.abs(g.normal(size=(c,))) + 0.1, jnp.float32)
    psi = jnp.asarray(np.abs(g.normal(size=(c,))) + 0.1, jnp.float32)
    dy, db = bn_backward_proposed(dx, xhat, omega, psi)
    dyr, dbr = ref.batchnorm_proposed_bwd(dx, xhat, omega, psi)
    np.testing.assert_allclose(np.asarray(dy), np.asarray(dyr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(db), np.asarray(dbr), atol=1e-4, rtol=1e-5)


def test_bn_backward_dbeta_is_colsum():
    g = rng(21)
    dx = jnp.asarray(g.normal(size=(16, 8)), jnp.float32)
    xhat = jnp.ones((16, 8))
    _, db = bn_backward_proposed(dx, xhat, jnp.ones((8,)), jnp.ones((8,)))
    np.testing.assert_allclose(np.asarray(db), np.asarray(jnp.sum(dx, 0)), atol=1e-5)


def test_bn_backward_psi_scales_inverse():
    g = rng(22)
    dx = jnp.asarray(g.normal(size=(16, 8)), jnp.float32)
    xhat = ref.sign(jnp.asarray(g.normal(size=(16, 8)), jnp.float32))
    om = jnp.ones((8,))
    dy1, _ = bn_backward_proposed(dx, xhat, om, jnp.ones((8,)))
    dy2, _ = bn_backward_proposed(dx, xhat, om, jnp.full((8,), 2.0))
    np.testing.assert_allclose(np.asarray(dy2), np.asarray(dy1) / 2, atol=1e-5)


# ---------------------------------- approximation-quality properties

def test_proposed_bwd_approximates_l1_bwd_when_mean_zero():
    """DESIGN.md invariant: for mu(x) ~ 0 the proposed backward is
    close to Eq. (1)'s exact l1 backward (the paper's derivation)."""
    g = rng(33)
    b, c = 512, 16
    y = jnp.asarray(g.normal(size=(b, c)), jnp.float32)
    beta = jnp.zeros((c,))
    x, mu, psi, om = ref.batchnorm_l1_fwd(y, beta)
    dx = jnp.asarray(g.normal(size=(b, c)), jnp.float32)

    dy_l1, _ = ref.batchnorm_l1_bwd(dx, x, beta, psi)
    dy_prop, _ = ref.batchnorm_proposed_bwd(dx, ref.sign(x), om, psi)
    # cosine similarity of gradient directions must be high
    a = np.asarray(dy_l1).ravel()
    p = np.asarray(dy_prop).ravel()
    cos = a @ p / (np.linalg.norm(a) * np.linalg.norm(p) + 1e-12)
    assert cos > 0.95, cos


def test_wgrad_binarize_and_attenuate():
    g = rng(40)
    dw = jnp.asarray(g.normal(size=(64, 32)), jnp.float32)
    dwh = ref.binarize_wgrad(dw)
    assert set(np.unique(np.asarray(dwh))) <= {-1.0, 1.0}
    att = ref.attenuate_wgrad(dwh, 64)
    np.testing.assert_allclose(np.abs(np.asarray(att)), 1 / np.sqrt(64), rtol=1e-6)
