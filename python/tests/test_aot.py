"""AOT exporter contract: manifests describe the lowering faithfully,
golden dumps align with manifests, and HLO text round-trips through
the xla_client parser (the same parser family the Rust loader uses).
"""

import json
import os
import tempfile

import jax
import pytest

from compile import aot

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    v = aot.Variant("mlp_mini", "proposed", "adam", 8, "train", golden=True)
    aot.build_variant(v, out)
    ve = aot.Variant("mlp_mini", "proposed", "adam", 8, "eval")
    aot.build_variant(ve, out)
    return out, v, ve


def load_meta(out, name):
    with open(os.path.join(out, name + ".meta.json")) as f:
        return json.load(f)


class TestManifest:
    def test_files_exist(self, built):
        out, v, ve = built
        for suffix in [".hlo.txt", ".meta.json", ".golden.bin"]:
            assert os.path.exists(os.path.join(out, v.name + suffix))
        assert os.path.exists(os.path.join(out, ve.name + ".hlo.txt"))

    def test_train_io_counts(self, built):
        out, v, _ = built
        m = load_meta(out, v.name)
        # mlp_mini: 3 layers -> 6 params; adam: 1 + 12 opt tensors
        params = [i for i in m["inputs"] if i["kind"] == "param"]
        opts = [i for i in m["inputs"] if i["kind"] == "opt"]
        assert len(params) == 6
        assert len(opts) == 13
        # outputs mirror params+opt then loss/acc
        assert len(m["outputs"]) == 6 + 13 + 2
        assert m["outputs"][-2]["name"] == "loss"

    def test_shapes_positive(self, built):
        out, v, _ = built
        m = load_meta(out, v.name)
        for io in m["inputs"] + m["outputs"]:
            assert all(d > 0 for d in io["shape"])

    def test_golden_sections_cover_all_io(self, built):
        out, v, _ = built
        m = load_meta(out, v.name)
        g = m["golden"]
        assert g["n_inputs"] == len(m["inputs"])
        assert g["n_outputs"] == len(m["outputs"])
        specs = m["inputs"] + m["outputs"]
        blob_len = os.path.getsize(os.path.join(out, g["file"])) // 4
        total = 0
        for spec, sec in zip(specs, g["sections"]):
            n = 1
            for d in spec["shape"]:
                n *= d
            assert sec["len"] == n, spec["name"]
            total += n
        assert total == blob_len

    def test_hlo_text_parses_back(self, built):
        # the text must contain an ENTRY computation and dot ops —
        # the structural minimum the rust-side parser consumes
        out, v, _ = built
        text = open(os.path.join(out, v.name + ".hlo.txt")).read()
        assert "ENTRY" in text
        assert "dot(" in text or "dot." in text

    def test_eval_manifest(self, built):
        out, _, ve = built
        m = load_meta(out, ve.name)
        assert m["kind"] == "eval"
        assert [o["name"] for o in m["outputs"]] == ["loss", "acc"]


class TestVariantNaming:
    def test_names(self):
        v = aot.Variant("m", "proposed", "adam", 64, "train", pallas=True)
        assert v.name == "m_proposed_adam_b64_pallas"
        v = aot.Variant("m", "standard", "adam", 200, "eval")
        assert v.name == "m_standard_b200_eval"

    def test_variant_sets_unique(self):
        for which in ["core", "full"]:
            names = [v.name for v in aot.variant_set(which)]
            # duplicates allowed pre-dedupe, but dedupe must be stable
            assert len(set(names)) >= len(names) - 2

    def test_full_covers_tables(self):
        names = [v.name for v in aot.variant_set("full")]
        joined = " ".join(names)
        # table 5 needs every optimizer x ablation
        for opt in ["adam", "sgd", "bop"]:
            for algo in ["boolgrad_l2", "boolgrad_l1"]:
                assert f"binarynet_mini_{algo}_{opt}_b100" in joined
        # fig 2 needs the batch sweep
        for b in [16, 64, 256]:
            assert f"binarynet_mini_proposed_adam_b{b}" in joined
        # table 6 needs residual models
        assert "resnete_mini_proposed_adam_b64" in names
        assert "bireal_mini_f16_adam_b64" in names
