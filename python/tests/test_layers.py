"""L2 layer semantics: custom-vjp backward rules implement the paper's
algorithms (not generic autodiff), precision emulation behaves, and
the NN (non-binary) reference path is truly unquantized."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed=0):
    return np.random.default_rng(seed)


class TestTrainConfig:
    def test_standard_is_all_f32(self):
        c = L.TrainConfig.standard()
        assert not c.grad_f16 and not c.wgrad_bool and c.bn == "l2"

    def test_proposed_is_fully_approximate(self):
        c = L.TrainConfig.proposed()
        assert c.grad_f16 and c.wgrad_bool and c.bn == "proposed"

    def test_ablation_names(self):
        for n in ["standard", "f16", "boolgrad_l2", "boolgrad_l1",
                  "proposed", "nn_standard", "nn_proposed"]:
            L.TrainConfig.ablation(n)
        with pytest.raises(KeyError):
            L.TrainConfig.ablation("nope")

    def test_nn_configs_disable_binarization(self):
        assert not L.TrainConfig.ablation("nn_standard").binarize
        assert not L.TrainConfig.ablation("nn_proposed").binarize


class TestQ16:
    def test_roundtrip_precision(self):
        x = jnp.array([1.0, 1.0001, 65504.0, 1e-8])
        q = L.q16(x)
        assert q[0] == 1.0
        assert q[1] == 1.0  # rounded away
        assert q[2] == 65504.0
        assert q[3] == 0.0 or abs(q[3]) < 1e-7  # sub-f16 underflow

    def test_disabled_passthrough(self):
        x = jnp.array([1.0001])
        np.testing.assert_array_equal(L.maybe_q16(x, False), x)


class TestBinarize:
    def test_forward_is_sign(self):
        cfg = L.TrainConfig.proposed()
        x = jnp.asarray(rng().normal(size=(8, 8)), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(L.binarize(x, cfg)), np.asarray(ref.sign(x))
        )

    def test_ste_gradient_cancellation(self):
        cfg = L.TrainConfig.proposed()
        x = jnp.array([[0.5, -0.5, 2.0, -2.0]])
        g = jax.grad(lambda v: jnp.sum(L.binarize(v, cfg)))(x)
        # |x| <= 1 passes gradient 1; |x| > 1 cancelled
        np.testing.assert_array_equal(np.asarray(g), [[1.0, 1.0, 0.0, 0.0]])

    def test_nn_identity(self):
        cfg = L.TrainConfig.ablation("nn_standard")
        x = jnp.array([[0.3, -4.0]])
        np.testing.assert_array_equal(np.asarray(L.binarize(x, cfg)), np.asarray(x))
        g = jax.grad(lambda v: jnp.sum(L.binarize(v, cfg) ** 2))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(2 * x))


class TestBinaryMatmul:
    def test_forward_binarizes_weights(self):
        cfg = L.TrainConfig.proposed()
        xhat = ref.sign(jnp.asarray(rng(1).normal(size=(4, 6)), jnp.float32))
        w = jnp.asarray(rng(2).normal(size=(6, 3)) * 0.1, jnp.float32)
        y = L.binary_matmul_op(xhat, w, cfg)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(xhat @ ref.sign(w)), atol=1e-6
        )

    def test_backward_binarizes_and_attenuates_wgrad(self):
        cfg = L.TrainConfig.proposed()
        k = 16
        xhat = ref.sign(jnp.asarray(rng(3).normal(size=(8, k)), jnp.float32))
        w = jnp.asarray(rng(4).normal(size=(k, 5)) * 0.1, jnp.float32)
        dw = jax.grad(
            lambda ww: jnp.sum(L.binary_matmul_op(xhat, ww, cfg)), 0
        )(w)
        # every nonzero entry is +/- 1/sqrt(k) (Alg. 2 lines 16+18)
        vals = np.unique(np.round(np.abs(np.asarray(dw)), 6))
        assert set(vals) <= {0.0, np.float32(round(1 / np.sqrt(k), 6))}

    def test_backward_standard_keeps_real_wgrad(self):
        cfg = L.TrainConfig.standard()
        xhat = ref.sign(jnp.asarray(rng(5).normal(size=(8, 16)), jnp.float32))
        w = jnp.asarray(rng(6).normal(size=(16, 5)) * 0.1, jnp.float32)
        dw = jax.grad(
            lambda ww: jnp.sum(L.binary_matmul_op(xhat, ww, cfg)), 0
        )(w)
        # dW = X̂^T dY with dY = 1: each entry = column sum of X̂
        want = np.asarray(xhat).T @ np.ones((8, 5), np.float32)
        np.testing.assert_allclose(np.asarray(dw), want, atol=1e-5)

    def test_weight_gradient_cancellation(self):
        cfg = L.TrainConfig.standard()
        xhat = jnp.ones((4, 2))
        w = jnp.array([[0.5, 1.5], [-1.5, 0.0]])
        dw = jax.grad(
            lambda ww: jnp.sum(L.binary_matmul_op(xhat, ww, cfg)), 0
        )(w)
        d = np.asarray(dw)
        assert d[0, 1] == 0.0 and d[1, 0] == 0.0  # |w| > 1 cancelled
        assert d[0, 0] != 0.0 and d[1, 1] != 0.0

    def test_grad_f16_rounds_dx(self):
        cfg = L.TrainConfig.ablation("f16")
        xhat = ref.sign(jnp.asarray(rng(7).normal(size=(4, 8)), jnp.float32))
        w = jnp.asarray(rng(8).normal(size=(8, 3)) * 0.1, jnp.float32)
        dx = jax.grad(
            lambda xx: jnp.sum(L.binary_matmul_op(xx, w, cfg) * 1.0001), 0
        )(xhat)
        # all dx values must be exactly representable in f16
        d = np.asarray(dx)
        np.testing.assert_array_equal(d, d.astype(np.float16).astype(np.float32))


class TestBatchNormOp:
    def _grad(self, cfg, seed=0, b=32, c=4):
        g = rng(seed)
        y = jnp.asarray(g.normal(size=(b, c)) * 2, jnp.float32)
        beta = jnp.asarray(g.normal(size=(c,)) * 0.1, jnp.float32)
        t = jnp.asarray(g.normal(size=(b, c)), jnp.float32)
        f = lambda yy, bb: jnp.sum(L.batchnorm_op(yy, bb, cfg) * t)
        dy, dbeta = jax.grad(f, (0, 1))(y, beta)
        return y, beta, t, dy, dbeta

    def test_l2_backward_matches_ref(self):
        cfg = L.TrainConfig.standard()
        y, beta, t, dy, dbeta = self._grad(cfg)
        xn, mu, psi = ref.batchnorm_l2_fwd(y, beta)
        want_dy, want_db = ref.batchnorm_l2_bwd(t, xn, beta, psi)
        np.testing.assert_allclose(np.asarray(dy), np.asarray(want_dy), atol=1e-5)
        np.testing.assert_allclose(np.asarray(dbeta), np.asarray(want_db), atol=1e-4)

    def test_proposed_backward_matches_ref(self):
        cfg = dataclasses.replace(L.TrainConfig.proposed(), grad_f16=False)
        y, beta, t, dy, dbeta = self._grad(cfg, seed=1)
        x, mu, psi, omega = ref.batchnorm_l1_fwd(y, beta)
        want_dy, want_db = ref.batchnorm_proposed_bwd(
            t, ref.sign(x - beta), omega, psi
        )
        np.testing.assert_allclose(np.asarray(dy), np.asarray(want_dy), atol=1e-5)
        np.testing.assert_allclose(np.asarray(dbeta), np.asarray(want_db), atol=1e-4)

    def test_dbeta_always_column_sum(self):
        for algo in ["standard", "boolgrad_l1", "proposed"]:
            cfg = dataclasses.replace(
                L.TrainConfig.ablation(algo), grad_f16=False
            )
            y, beta, t, dy, dbeta = self._grad(cfg, seed=2)
            np.testing.assert_allclose(
                np.asarray(dbeta), np.asarray(jnp.sum(t, 0)), atol=1e-4
            )


class TestConvAndPool:
    def test_binary_conv_shape(self):
        cfg = L.TrainConfig.proposed()
        x = jnp.asarray(rng(9).normal(size=(2, 8, 8, 3)), jnp.float32)
        w = jnp.asarray(rng(10).normal(size=(3, 3, 3, 5)) * 0.1, jnp.float32)
        y = L.binary_conv(x, w, cfg, first=True)
        assert y.shape == (2, 8, 8, 5)

    def test_im2col_matches_conv(self):
        # binary conv via im2col == lax.conv on sign values
        cfg = L.TrainConfig.proposed()
        x = jnp.asarray(rng(11).normal(size=(1, 6, 6, 2)), jnp.float32)
        w = jnp.asarray(rng(12).normal(size=(3, 3, 2, 4)) * 0.1, jnp.float32)
        # binary_conv expects a pre-binarized input (apply_model
        # binarizes before the conv); zero-padding then yields
        # sgn(0) = +1... no: padding happens on the +/-1 map, and
        # lax.conv pads with 0 — both paths pad the *signed* map, so
        # they agree.
        y = L.binary_conv(ref.sign(x), w, cfg, first=False)
        want = jax.lax.conv_general_dilated(
            ref.sign(x),
            ref.sign(w),
            (1, 1),
            "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)

    def test_maxpool(self):
        x = jnp.arange(16.0).reshape(1, 4, 4, 1)
        y = L.maxpool2(x)
        np.testing.assert_array_equal(
            np.asarray(y)[0, :, :, 0], [[5.0, 7.0], [13.0, 15.0]]
        )

    def test_loss_and_accuracy(self):
        logits = jnp.array([[10.0, 0.0], [0.0, 10.0], [10.0, 0.0]])
        y = jnp.array([[1.0, 0.0], [1.0, 0.0], [1.0, 0.0]])
        assert float(L.accuracy(logits, y)) == pytest.approx(2 / 3)
        assert float(L.softmax_xent(logits, y)) > 0.0
