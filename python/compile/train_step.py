"""L2 train/eval step assembly: fwd + Alg. 1/2 bwd + optimizer update.

One jitted, AOT-exportable function per (model, algo, optimizer)
variant.  Signature (all f32 at the HLO boundary; reduced precision is
emulated *inside*, realized by the Rust engines):

    step(*params, *opt_state, x, y_onehot, lr)
        -> (*params', *opt_state', loss, acc)

    evalf(*params, x, y_onehot) -> (loss, acc)

Optimizers (paper Sec. 6.1.1):
    adam  Kingma & Ba; latent f32/f16 weights, clipped to [-1, 1]
    sgd   SGD with momentum 0.9
    bop   Helwegen et al.'s weightless BNN optimizer: binary weights,
          gradient EMA m, flip where m*w exceeds tau; beta (BN bias)
          still trained with Adam as in the Bop paper.

The weight-update attenuation by 1/sqrt(N_l) for binarized gradients
(Alg. 2 line 18) is applied inside the matmul vjp (layers.py), so the
optimizers below are algorithm-agnostic.
"""

import functools
from typing import List

import jax
import jax.numpy as jnp

from . import layers as L
from . import models as M

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
SGD_MOMENTUM = 0.9
BOP_TAU = 1e-8


def _q_params(flat, cfg):
    """Emulate f16 latent-weight storage (Table 2's W row)."""
    return [L.maybe_q16(p, cfg.weight_f16) for p in flat]


def loss_fn(spec, cfg, params, x, y):
    logits = M.apply_model(spec, cfg, params, x)
    return L.softmax_xent(logits, y), logits


def opt_state_shapes(spec: M.ModelSpec, optimizer: str):
    """Flat opt-state array shapes (documented in the manifest)."""
    pshapes = [s for pair in M.param_shapes(spec) for s in pair]
    if optimizer == "adam":
        # t, then m_i and v_i for every param
        return [()] + pshapes + pshapes
    if optimizer == "sgd":
        return pshapes
    if optimizer == "bop":
        # gradient EMA for weights, plus Adam (t, m, v) for betas
        wshapes = [p[0] for p in M.param_shapes(spec)]
        bshapes = [p[1] for p in M.param_shapes(spec)]
        return wshapes + [()] + bshapes + bshapes
    raise ValueError(optimizer)


def init_opt_state(spec, optimizer):
    return [jnp.zeros(s, jnp.float32) for s in opt_state_shapes(spec, optimizer)]


def _adam_update(p, g, m, v, t, lr):
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m / (1 - ADAM_B1 ** t)
    vhat = v / (1 - ADAM_B2 ** t)
    return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def make_train_step(spec: M.ModelSpec, cfg: L.TrainConfig, optimizer: str):
    """Returns step(params, opt, x, y, lr) over *lists* of arrays."""

    nparams = 2 * spec.num_param_layers()

    def step(params: List, opt: List, x, y, lr):
        params = _q_params(params, cfg)
        (loss, logits), grads = jax.value_and_grad(
            lambda ps: loss_fn(spec, cfg, ps, x, y), has_aux=True
        )(params)
        acc = L.accuracy(logits, y)
        # Gradients of W arrive pre-binarized/attenuated from the vjp
        # when cfg.wgrad_bool; betas are always small f16/f32 rows.
        if optimizer == "adam":
            t = opt[0] + 1.0
            ms, vs = opt[1:1 + nparams], opt[1 + nparams:]
            new_p, new_m, new_v = [], [], []
            for i, (p, g) in enumerate(zip(params, grads)):
                p2, m2, v2 = _adam_update(p, g, ms[i], vs[i], t, lr)
                if i % 2 == 0:           # weight: clip latent to [-1,1]
                    p2 = jnp.clip(p2, -1.0, 1.0)
                new_p.append(L.maybe_q16(p2, cfg.weight_f16))
                new_m.append(L.maybe_q16(m2, cfg.weight_f16))
                new_v.append(L.maybe_q16(v2, cfg.weight_f16))
            new_opt = [t] + new_m + new_v
        elif optimizer == "sgd":
            new_p, new_vel = [], []
            for i, (p, g) in enumerate(zip(params, grads)):
                vel = SGD_MOMENTUM * opt[i] + g
                p2 = p - lr * vel
                if i % 2 == 0:
                    p2 = jnp.clip(p2, -1.0, 1.0)
                new_p.append(L.maybe_q16(p2, cfg.weight_f16))
                new_vel.append(L.maybe_q16(vel, cfg.weight_f16))
            new_opt = new_vel
        elif optimizer == "bop":
            nlayers = nparams // 2
            emas = opt[:nlayers]
            t = opt[nlayers] + 1.0
            bms = opt[nlayers + 1:nlayers + 1 + nlayers]
            bvs = opt[nlayers + 1 + nlayers:]
            gamma = lr   # adaptivity rate tied to the lr input
            new_p, new_ema, new_bm, new_bv = [], [], [], []
            for i in range(nlayers):
                w, beta = params[2 * i], params[2 * i + 1]
                gw, gb = grads[2 * i], grads[2 * i + 1]
                ema = (1 - gamma) * emas[i] + gamma * gw
                flip = (w * ema) > BOP_TAU
                w2 = jnp.where(flip, -w, w)
                b2, m2, v2 = _adam_update(beta, gb, bms[i], bvs[i], t, 0.001)
                new_p += [w2, L.maybe_q16(b2, cfg.weight_f16)]
                new_ema.append(L.maybe_q16(ema, cfg.weight_f16))
                new_bm.append(m2)
                new_bv.append(v2)
            new_opt = new_ema + [t] + new_bm + new_bv
        else:
            raise ValueError(optimizer)
        return new_p, new_opt, loss, acc

    return step


def make_eval_step(spec: M.ModelSpec, cfg: L.TrainConfig):
    def evalf(params: List, x, y):
        loss, logits = loss_fn(spec, cfg, params, x, y)
        return loss, L.accuracy(logits, y)
    return evalf


# ------------------------------------------------------- flat wrappers
# The AOT boundary is positional: *params, *opt, x, y, lr.

def make_flat_train_step(spec, cfg, optimizer):
    step = make_train_step(spec, cfg, optimizer)
    nparams = 2 * spec.num_param_layers()
    nopt = len(opt_state_shapes(spec, optimizer))

    def flat(*args):
        params = list(args[:nparams])
        opt = list(args[nparams:nparams + nopt])
        x, y, lr = args[nparams + nopt:]
        new_p, new_opt, loss, acc = step(params, opt, x, y, lr)
        return tuple(new_p) + tuple(new_opt) + (loss, acc)

    return flat, nparams, nopt


def make_flat_eval_step(spec, cfg):
    evalf = make_eval_step(spec, cfg)
    nparams = 2 * spec.num_param_layers()

    def flat(*args):
        params = list(args[:nparams])
        x, y = args[nparams:]
        loss, acc = evalf(params, x, y)
        return (loss, acc)

    return flat, nparams


def init_bop_weights(params):
    """Bop stores binary weights: replace latent init by its sign."""
    out = []
    for i, p in enumerate(params):
        out.append(jnp.where(p >= 0, 1.0, -1.0) if i % 2 == 0 else p)
    return out
