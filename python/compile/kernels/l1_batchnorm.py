"""Pallas kernel: l1 batch-normalization forward — Alg. 2 lines 5-8.

Per output channel m:
    mu      = mean_B(y)
    psi     = ||y - mu||_1 / B + eps      (mean absolute deviation)
    x_next  = (y - mu) / psi + beta
    omega   = ||x_next||_1 / B            (mean magnitude, retained for
                                           the proposed backward)

Tiling: 1-D grid over channel tiles.  Each grid step holds one
(B, bc) activation block plus four (bc,) statistic rows in VMEM, so the
whole batch-reduction for a channel happens in one step — no cross-step
accumulation, no HBM round-trip for partial sums.  VMEM per step at
(B=256, bc=128, f32) = 2*B*bc*4 + O(bc) ≈ 256 KiB.

All reductions run on the VPU (element-wise + cross-lane adds); there
is no MXU work here.  interpret=True for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_C = 128


def _kernel(y_ref, beta_ref, x_ref, mu_ref, psi_ref, om_ref, *, batch, eps):
    y = y_ref[...]
    mu = jnp.mean(y, axis=0)
    cent = y - mu[None, :]
    psi = jnp.sum(jnp.abs(cent), axis=0) / batch + eps
    x = cent / psi[None, :] + beta_ref[...][None, :]
    x_ref[...] = x
    mu_ref[...] = mu
    psi_ref[...] = psi
    om_ref[...] = jnp.mean(jnp.abs(x), axis=0)


@functools.partial(jax.jit, static_argnames=("block_c", "eps"))
def l1_batchnorm_fwd(y, beta, block_c=DEFAULT_BLOCK_C, eps=1e-5):
    """Forward l1 batch norm.  y: (B, C) float; beta: (C,) float.
    Returns (x_next, mu, psi, omega): (B, C), (C,), (C,), (C,)."""
    b, c = y.shape
    bc = min(block_c, c)
    pad = (-c) % bc
    if pad:
        # Padded channels normalize garbage zeros; sliced off below.
        y = jnp.pad(y, ((0, 0), (0, pad)))
        beta = jnp.pad(beta, (0, pad))
    cp = y.shape[1]
    grid = (cp // bc,)

    x, mu, psi, om = pl.pallas_call(
        functools.partial(_kernel, batch=float(b), eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bc), lambda j: (0, j)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((b, bc), lambda j: (0, j)),
            pl.BlockSpec((bc,), lambda j: (j,)),
            pl.BlockSpec((bc,), lambda j: (j,)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, cp), jnp.float32),
            jax.ShapeDtypeStruct((cp,), jnp.float32),
            jax.ShapeDtypeStruct((cp,), jnp.float32),
            jax.ShapeDtypeStruct((cp,), jnp.float32),
        ],
        interpret=True,
    )(y, beta)
    return x[:, :c], mu[:c], psi[:c], om[:c]


def vmem_bytes(batch, block_c=DEFAULT_BLOCK_C, dtype_bytes=4):
    """Modeled VMEM residency per grid step: input block + output block
    + 4 statistic rows (mu, psi, omega, beta)."""
    return (2 * batch * block_c + 4 * block_c) * dtype_bytes
