# L1: Pallas kernels for the paper's compute hot-spots, each with a
# pure-jnp oracle in ref.py (tested in python/tests/).
from . import ref  # noqa: F401
from .binary_matmul import binary_matmul  # noqa: F401
from .l1_batchnorm import l1_batchnorm_fwd  # noqa: F401
from .bn_backward import bn_backward_proposed  # noqa: F401
from .sign import sign_ste  # noqa: F401
