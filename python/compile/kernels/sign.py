"""Pallas kernel: sign + straight-through-estimator mask.

Forward binarization (Alg. 1/2 line 2) and the gradient-cancellation
mask of Courbariaux & Bengio: d sgn(x)/dx ~= 1{|x| <= 1}.  Emitting
both from one kernel means the f32 activations are read from HBM once;
the mask is a bool (1 bit logical) and the sign a bool, which is the
entire point of the paper — nothing f32 survives the forward pass.

Element-wise only: a 1-D grid over row tiles, trivially VPU-bound.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_R = 256


def _kernel(x_ref, s_ref, m_ref, *, clip):
    x = x_ref[...]
    s_ref[...] = jnp.where(x >= 0, 1.0, -1.0)
    m_ref[...] = (jnp.abs(x) <= clip).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_r", "clip"))
def sign_ste(x, block_r=DEFAULT_BLOCK_R, clip=1.0):
    """x: (R, C) float.  Returns (sgn(x), ste_mask(x)) as f32 arrays
    with values in {-1,+1} and {0,1} respectively."""
    r, c = x.shape
    br = min(block_r, r)
    pad = (-r) % br
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    rp = x.shape[0]

    s, m = pl.pallas_call(
        functools.partial(_kernel, clip=clip),
        grid=(rp // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, c), jnp.float32),
            jax.ShapeDtypeStruct((rp, c), jnp.float32),
        ],
        interpret=True,
    )(x)
    return s[:r], m[:r]
