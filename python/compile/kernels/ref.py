"""Pure-jnp reference oracles for every Pallas kernel.

These implement Algorithms 1 and 2 of Wang et al., "Enabling Binary
Neural Network Training on the Edge", verbatim, with no tiling and no
Pallas machinery.  Every Pallas kernel in this package is tested
against the function of the same name here (see python/tests/).

Shape conventions (fully-connected exposition of the paper):
    y, x, dx : (B, C)   batch-major activations / matmul outputs
    w        : (K, C)   fan-in x fan-out weights
    beta, mu, psi, omega : (C,) per-output-channel statistics
Convolutional layers reach these kernels through im2col, so (B, C)
really means (batch*spatial, channels) there; nothing changes.
"""

import jax.numpy as jnp


def sign(x):
    """Paper's sgn: maps to {-1, +1}; sgn(0) := +1 so the codomain is
    exactly the binary encoding."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def binary_matmul(x, w):
    """Alg. 1/2 line 4: Y = sgn(X) . sgn(W).

    The XNOR-popcount GEMM of BNN inference, expressed as a +/-1
    matmul (the canonical MXU realization on TPU).
    """
    return sign(x) @ sign(w)


def ste_mask(x, clip=1.0):
    """Gradient-cancellation mask of Courbariaux & Bengio:
    d sgn(x)/dx ~= 1{|x| <= clip} (straight-through estimator)."""
    return (jnp.abs(x) <= clip).astype(x.dtype)


def mean_abs(x):
    """Per-channel mean magnitude ||x||_1 / B (Alg. 2 line 8)."""
    return jnp.mean(jnp.abs(x), axis=0)


# --------------------------------------------------------------------
# Batch normalization, standard (l2)  -- Alg. 1 lines 5-7 and 10-13
# --------------------------------------------------------------------

def batchnorm_l2_fwd(y, beta, eps=1e-5):
    """Alg. 1 lines 5-7. Returns (x_next, mu, psi) with psi = sigma(y).

    No trainable scale (gamma): irrelevant for BNNs since the output
    is binarized immediately (paper Sec. 3).
    """
    mu = jnp.mean(y, axis=0)
    psi = jnp.sqrt(jnp.mean((y - mu) ** 2, axis=0) + eps)
    x_next = (y - mu) / psi + beta
    return x_next, mu, psi


def batchnorm_l2_bwd(dx, x_next, beta, psi):
    """Alg. 1 lines 10-13.  [x_{l+1}] denotes the *normalized*
    activations (x_next - beta); v = dx / psi.

        dy    = v - mu(v) - mu(v . xn) xn
        dbeta = sum_B dx
    """
    xn = x_next - beta
    v = dx / psi
    dy = v - jnp.mean(v, axis=0) - jnp.mean(v * xn, axis=0) * xn
    dbeta = jnp.sum(dx, axis=0)
    return dy, dbeta


# --------------------------------------------------------------------
# Batch normalization, l1  -- Alg. 2 lines 5-8 (fwd) and Eq. (1) (bwd)
# --------------------------------------------------------------------

def batchnorm_l1_fwd(y, beta, eps=1e-5):
    """Alg. 2 lines 5-8.  psi is the mean absolute deviation
    ||y - mu||_1 / B; also emits omega = ||x_next||_1 / B, the
    per-channel mean magnitude used by the proposed backward."""
    b = y.shape[0]
    mu = jnp.mean(y, axis=0)
    psi = jnp.sum(jnp.abs(y - mu), axis=0) / b + eps
    x_next = (y - mu) / psi + beta
    omega = mean_abs(x_next)
    return x_next, mu, psi, omega


def batchnorm_l1_bwd(dx, x_next, beta, psi):
    """Eq. (1): the l1 backward *before* the BNN-specific step.

        v  = dx / psi
        dy = v - mu(v) - mu(v . xn) sgn(xn)
    with xn the normalized activations (x_next - beta).
    """
    xn = x_next - beta
    v = dx / psi
    dy = v - jnp.mean(v, axis=0) - jnp.mean(v * xn, axis=0) * sign(xn)
    dbeta = jnp.sum(dx, axis=0)
    return dy, dbeta


# --------------------------------------------------------------------
# Batch normalization, proposed  -- Alg. 2 lines 10-13
# --------------------------------------------------------------------

def batchnorm_proposed_bwd(dx, xhat, omega, psi):
    """Alg. 2 lines 10-13 — the paper's key contribution.

    Only *binary* activations xhat = sgn(xn) plus the per-channel mean
    magnitude omega survive from the forward pass:

        v  = dx / psi
        dy = v - mu(v) - mu(v . (xhat omega)) xhat
           = v - mu(v) - omega mu(v . xhat) xhat
        dbeta = sum_B dx
    """
    v = dx / psi
    dy = v - jnp.mean(v, axis=0) - (omega * jnp.mean(v * xhat, axis=0)) * xhat
    dbeta = jnp.sum(dx, axis=0)
    return dy, dbeta


# --------------------------------------------------------------------
# Weight-gradient binarization  -- Alg. 2 lines 16, 18
# --------------------------------------------------------------------

def binarize_wgrad(dw):
    """Alg. 2 line 16: dW_hat = sgn(dW)."""
    return sign(dw)


def attenuate_wgrad(dw_hat, fan_in):
    """Alg. 2 line 18: the update consumes dW_hat / sqrt(N_l)."""
    return dw_hat / jnp.sqrt(jnp.asarray(fan_in, dw_hat.dtype))
