"""Pallas kernel: binary (sign-sign) matmul — Alg. 1/2 line 4.

Y = sgn(X) @ sgn(W), the XNOR-popcount GEMM of BNN training, expressed
as a +/-1 matmul so it maps onto the TPU MXU systolic array (TPUs have
no popcount datapath; feeding the MXU +/-1 operands in bf16 is the
canonical realization — see DESIGN.md §Hardware-Adaptation).

Tiling: a 3-D grid (M/bm, N/bn, K/bk).  Each grid step holds one
(bm, bk) X-tile, one (bk, bn) W-tile and the (bm, bn) accumulator in
VMEM; the K axis is the innermost (fastest-varying) grid dimension so
the output tile stays resident while partial products accumulate —
the HBM<->VMEM schedule a CUDA kernel would express with threadblocks
is expressed here with BlockSpec index maps.

VMEM per grid step (f32): bm*bk + bk*bn + bm*bn floats.  With the
default (128, 128, 128) tiles that is 3 * 64 KiB = 192 KiB — far under
the ~16 MiB VMEM budget, leaving room for double buffering.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; structure (not wallclock) is what carries to real TPUs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bn, bk)


def _kernel(x_ref, w_ref, o_ref, *, nsteps_k):
    """One (bm, bn) output tile; K-accumulation across grid steps."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xs = jnp.where(x_ref[...] >= 0, 1.0, -1.0).astype(jnp.float32)
    ws = jnp.where(w_ref[...] >= 0, 1.0, -1.0).astype(jnp.float32)
    o_ref[...] += jnp.dot(xs, ws, preferred_element_type=jnp.float32)


def _pad_to(x, multiple, axis, value=0.0):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block",))
def binary_matmul(x, w, block=DEFAULT_BLOCK):
    """Y = sgn(X) @ sgn(W) via the tiled Pallas kernel.

    x: (M, K) float; w: (K, N) float.  Returns (M, N) float32.
    Inputs are zero-padded to tile multiples.  Since sgn(0) = +1, each
    zero-padded K lane contributes exactly +1*+1 = +1 to *every*
    output element, so the constant pad_k is subtracted afterwards;
    M/N padding is simply sliced off.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = block
    bm, bn, bk = min(bm, _ceil_mult(m)), min(bn, _ceil_mult(n)), min(bk, _ceil_mult(k))

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)

    # Zero-padded K lanes contribute sgn(0)*sgn(0) = +1 each; remove.
    pad_k = kp - k
    if pad_k:
        out = out - float(pad_k)
    return out[:m, :n]


def _ceil_mult(dim, base=8):
    """Smallest multiple of `base` >= dim (for tiny test shapes)."""
    return ((dim + base - 1) // base) * base


def vmem_bytes(block=DEFAULT_BLOCK, dtype_bytes=4):
    """Modeled VMEM residency per grid step (see module docstring)."""
    bm, bn, bk = block
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


def mxu_utilization_estimate(m, k, n, block=DEFAULT_BLOCK):
    """Fraction of MXU issue slots doing useful work for an (m,k,n)
    problem under this tiling: useful MACs / (grid steps * bm*bn*bk).
    Padding waste is the only structural inefficiency."""
    bm, bn, bk = block
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    issued = gm * gn * gk * bm * bn * bk
    return (m * k * n) / issued
