"""Pallas kernel: proposed batch-norm backward — Alg. 2 lines 10-13.

The paper's key memory contribution: backward propagation through batch
normalization using only *binary* retained activations xhat = sgn(xn)
plus per-channel mean magnitudes omega.  Per channel m:

    v      = dx / psi
    dy     = v - mu(v) - (omega * mu(v . xhat)) . xhat
    dbeta  = sum_B dx

Tiling mirrors the forward kernel: a 1-D grid over channel tiles, each
grid step reducing a full (B, bc) block in VMEM.  The binary xhat block
would occupy B*bc bits on a real TPU (int8 at worst under Mosaic);
modeled VMEM below accounts xhat at 1 byte/element.

interpret=True for CPU-PJRT executability.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_C = 128


def _kernel(dx_ref, xhat_ref, om_ref, psi_ref, dy_ref, db_ref):
    dx = dx_ref[...]
    xhat = xhat_ref[...]
    v = dx / psi_ref[...][None, :]
    mu_v = jnp.mean(v, axis=0)
    mu_vx = jnp.mean(v * xhat, axis=0)
    dy_ref[...] = v - mu_v[None, :] - (om_ref[...] * mu_vx)[None, :] * xhat
    db_ref[...] = jnp.sum(dx, axis=0)


@functools.partial(jax.jit, static_argnames=("block_c",))
def bn_backward_proposed(dx, xhat, omega, psi, block_c=DEFAULT_BLOCK_C):
    """dx: (B, C); xhat: (B, C) in {-1,+1}; omega, psi: (C,).
    Returns (dy, dbeta): (B, C), (C,)."""
    b, c = dx.shape
    bc = min(block_c, c)
    pad = (-c) % bc
    if pad:
        dx = jnp.pad(dx, ((0, 0), (0, pad)))
        xhat = jnp.pad(xhat, ((0, 0), (0, pad)), constant_values=1.0)
        omega = jnp.pad(omega, (0, pad))
        psi = jnp.pad(psi, (0, pad), constant_values=1.0)  # avoid /0
    cp = dx.shape[1]
    grid = (cp // bc,)

    dy, dbeta = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bc), lambda j: (0, j)),
            pl.BlockSpec((b, bc), lambda j: (0, j)),
            pl.BlockSpec((bc,), lambda j: (j,)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((b, bc), lambda j: (0, j)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, cp), jnp.float32),
            jax.ShapeDtypeStruct((cp,), jnp.float32),
        ],
        interpret=True,
    )(dx, xhat, omega, psi)
    return dy[:, :c], dbeta[:c]


def vmem_bytes(batch, block_c=DEFAULT_BLOCK_C):
    """Modeled VMEM per grid step: f32 dx + dy blocks, 1-byte xhat
    block, three statistic rows."""
    return batch * block_c * (4 + 4 + 1) + 3 * block_c * 4
