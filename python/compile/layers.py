"""L2 layers: the paper's training algorithms as custom-vjp JAX ops.

Backward rules implement Algorithms 1 and 2 of Wang et al. *verbatim*
— not generic autodiff.  A `TrainConfig` selects between the standard
flow (Alg. 1), the ablation points of Table 5, and the full proposed
flow (Alg. 2):

    bn        : 'l2' | 'l1' | 'proposed'
    grad_f16  : emulate float16 storage of dY / dX (round-trip convert)
    wgrad_bool: binarize weight gradients, attenuate by 1/sqrt(fan_in)
    use_pallas: route matmuls/BN through the L1 Pallas kernels so they
                lower into the exported HLO (False = pure-jnp ref ops,
                numerically identical, used for fast sweeps)

Precision emulation: the exported HLO computes in f32 and *rounds
through* f16/bool exactly where Alg. 2 stores reduced-precision data.
The storage saving itself is realized (and measured) by the Rust naive
engine and priced by the Rust memory model; this layer guarantees the
numerics match what that storage implies.
"""

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.binary_matmul import binary_matmul as pallas_binary_matmul
from .kernels.l1_batchnorm import l1_batchnorm_fwd as pallas_l1_bn_fwd
from .kernels.bn_backward import bn_backward_proposed as pallas_bn_bwd
from .kernels.sign import sign_ste as pallas_sign_ste


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Selects one row of Table 5 (and Table 6's ablation columns)."""
    bn: str = "proposed"          # 'l2' | 'l1' | 'proposed'
    grad_f16: bool = True         # dY/dX stored as f16
    wgrad_bool: bool = True       # dW binarized (Alg. 2 line 16)
    weight_f16: bool = True       # latent W stored as f16
    use_pallas: bool = False      # route through L1 Pallas kernels
    ste_clip: float = 1.0
    # False = non-binary reference network (Table 3's "NN" columns):
    # real-valued weights and activations, same topology/approximations
    binarize: bool = True

    @staticmethod
    def standard():
        """Alg. 1: everything float32, l2 batch norm."""
        return TrainConfig(bn="l2", grad_f16=False, wgrad_bool=False,
                           weight_f16=False)

    @staticmethod
    def proposed(use_pallas: bool = False):
        """Alg. 2: the paper's full scheme."""
        return TrainConfig(bn="proposed", grad_f16=True, wgrad_bool=True,
                           weight_f16=True, use_pallas=use_pallas)

    @staticmethod
    def ablation(name: str):
        """Table 5 rows: 'standard', 'f16', 'boolgrad_l2',
        'boolgrad_l1', 'proposed'."""
        return {
            "standard": TrainConfig.standard(),
            "f16": TrainConfig(bn="l2", grad_f16=True, wgrad_bool=False,
                               weight_f16=True),
            "boolgrad_l2": TrainConfig(bn="l2", grad_f16=True,
                                       wgrad_bool=True, weight_f16=True),
            "boolgrad_l1": TrainConfig(bn="l1", grad_f16=True,
                                       wgrad_bool=True, weight_f16=True),
            "proposed": TrainConfig.proposed(),
            # Table 3 reference: non-binary nets, standard vs the same
            # approximations the BNN gets (the robustness asymmetry)
            "nn_standard": dataclasses.replace(TrainConfig.standard(),
                                               binarize=False),
            "nn_proposed": dataclasses.replace(TrainConfig.proposed(),
                                               binarize=False),
        }[name]


def q16(x):
    """Round-trip through float16: the storage-precision emulation."""
    return x.astype(jnp.float16).astype(jnp.float32)


def maybe_q16(x, enabled):
    return q16(x) if enabled else x


# ---------------------------------------------------------------------
# sgn with straight-through estimator (Alg. 1/2 line 2 + omitted
# "intricacy": activation gradient cancellation 1{|x|<=1}).
# ---------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def binarize(x, cfg: TrainConfig):
    if not cfg.binarize:
        return x
    return _sign_fwd_only(x, cfg)


def _sign_fwd_only(x, cfg):
    if cfg.use_pallas and x.ndim == 2:
        s, _ = pallas_sign_ste(x, clip=cfg.ste_clip)
        return s
    return ref.sign(x)


def _binarize_fwd(x, cfg):
    if not cfg.binarize:
        # identity with pass-through gradient (NN reference net)
        return x, jnp.ones((1,), jnp.bool_)
    if cfg.use_pallas and x.ndim == 2:
        s, m = pallas_sign_ste(x, clip=cfg.ste_clip)
    else:
        s, m = ref.sign(x), ref.ste_mask(x, cfg.ste_clip)
    # Residual is the 1-bit STE mask only — never the f32 activations.
    return s, m.astype(jnp.bool_)


def _binarize_bwd(cfg, mask, g):
    if not cfg.binarize:
        return (maybe_q16(g, cfg.grad_f16),)
    gx = jnp.where(mask, g, 0.0)
    return (maybe_q16(gx, cfg.grad_f16),)


binarize.defvjp(_binarize_fwd, _binarize_bwd)


# ---------------------------------------------------------------------
# Binary matmul layer (Alg. lines 3-4 fwd; 14-16 bwd).
#   y = xhat @ sgn(W); dx = dy What^T; dW = xhat^T dy (then binarized).
# Residuals: xhat (1-bit) and What (1-bit) only.
# ---------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def binary_matmul_op(xhat, w, cfg: TrainConfig):
    if not cfg.binarize:
        return xhat @ w
    what = _sign_fwd_only(w, cfg)
    return xhat @ what


def _bmm_fwd(xhat, w, cfg):
    if not cfg.binarize:
        return xhat @ w, (xhat, w, jnp.ones_like(w, jnp.bool_))
    if cfg.use_pallas:
        # Kernel binarizes internally; xhat is already +/-1 (idempotent).
        y = pallas_binary_matmul(xhat, w)
        what = ref.sign(w)
    else:
        what = ref.sign(w)
        y = xhat @ what
    return y, (xhat, what, jnp.abs(w) <= 1.0)


def _bmm_bwd(cfg, res, gy):
    xhat, what, wmask = res
    gy = maybe_q16(gy, cfg.grad_f16)
    dx = maybe_q16(gy @ what.T, cfg.grad_f16)
    dw = xhat.T @ gy
    if cfg.wgrad_bool:
        # Alg. 2 lines 16 + 18: binarize then attenuate by 1/sqrt(N_l).
        fan_in = xhat.shape[-1]
        dw = ref.binarize_wgrad(dw) / jnp.sqrt(jnp.float32(fan_in))
    # Weight gradient cancellation (Courbariaux): zero where |w| > 1.
    dw = jnp.where(wmask, dw, 0.0)
    return dx, dw


binary_matmul_op.defvjp(_bmm_fwd, _bmm_bwd)


# ---------------------------------------------------------------------
# First-layer matmul: real-valued inputs, binary weights (standard BNN
# practice — the paper keeps the first layer unquantized on the input
# side).  Residual: the f32 input (it is the *dataset* batch, which is
# resident anyway — the paper's memory model does not charge it to X).
# ---------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def first_matmul_op(x, w, cfg: TrainConfig):
    if not cfg.binarize:
        return x @ w
    return x @ _sign_fwd_only(w, cfg)


def _fmm_fwd(x, w, cfg):
    if not cfg.binarize:
        return x @ w, (x, w, jnp.ones_like(w, jnp.bool_))
    what = ref.sign(w)
    return x @ what, (x, what, jnp.abs(w) <= 1.0)


def _fmm_bwd(cfg, res, gy):
    x, what, wmask = res
    gy = maybe_q16(gy, cfg.grad_f16)
    dx = maybe_q16(gy @ what.T, cfg.grad_f16)
    dw = x.T @ gy
    if cfg.wgrad_bool:
        fan_in = x.shape[-1]
        dw = ref.binarize_wgrad(dw) / jnp.sqrt(jnp.float32(fan_in))
    dw = jnp.where(wmask, dw, 0.0)
    return dx, dw


first_matmul_op.defvjp(_fmm_fwd, _fmm_bwd)


# ---------------------------------------------------------------------
# Batch normalization (channel-wise over axis 0), three variants.
# The custom bwd consumes exactly the residuals the paper retains:
#   l2 / l1  : f32 normalized activations (the red dependency, Fig. 1)
#   proposed : 1-bit xhat + per-channel omega             (Alg. 2)
# ---------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def batchnorm_op(y, beta, cfg: TrainConfig):
    if cfg.bn == "l2":
        x, _, _ = ref.batchnorm_l2_fwd(y, beta)
    else:
        x = _l1_fwd(y, beta, cfg)[0]
    return x


def _l1_fwd(y, beta, cfg):
    if cfg.use_pallas and y.ndim == 2:
        return pallas_l1_bn_fwd(y, beta)
    return ref.batchnorm_l1_fwd(y, beta)


def _bn_fwd(y, beta, cfg):
    if cfg.bn == "l2":
        x, mu, psi = ref.batchnorm_l2_fwd(y, beta)
        res = (x - beta, psi)
    elif cfg.bn == "l1":
        x, mu, psi, _ = _l1_fwd(y, beta, cfg)
        res = (x - beta, psi)
    else:  # proposed: retain ONLY sgn(xn) and omega (+ psi row)
        x, mu, psi, omega = _l1_fwd(y, beta, cfg)
        res = (ref.sign(x - beta), omega, psi)
    return x, res


def _bn_bwd(cfg, res, gx):
    gx = maybe_q16(gx, cfg.grad_f16)
    if cfg.bn == "l2":
        xn, psi = res
        dy, dbeta = ref.batchnorm_l2_bwd(gx, xn, 0.0, psi)
    elif cfg.bn == "l1":
        xn, psi = res
        dy, dbeta = ref.batchnorm_l1_bwd(gx, xn, 0.0, psi)
    else:
        xhat, omega, psi = res
        if cfg.use_pallas and gx.ndim == 2:
            dy, dbeta = pallas_bn_bwd(gx, xhat, omega, psi)
        else:
            dy, dbeta = ref.batchnorm_proposed_bwd(gx, xhat, omega, psi)
    return maybe_q16(dy, cfg.grad_f16), dbeta


batchnorm_op.defvjp(_bn_fwd, _bn_bwd)


# ---------------------------------------------------------------------
# Convolution via im2col: patches -> the same binary matmul kernels.
# ---------------------------------------------------------------------

def im2col(x, kh, kw, stride=1, padding="SAME"):
    """x: (B, H, W, C) -> (B*OH*OW, kh*kw*C) patch matrix.

    `conv_general_dilated_patches` emits the feature axis in
    channel-major (C, kh, kw) order; we transpose to (kh, kw, C) so
    the weight matrix layout matches `w.reshape(kh*kw*C, F)` — and the
    Rust naive engine's layout (see rust/src/naive/standard.rs).
    """
    cin = x.shape[-1]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    b, oh, ow, k = patches.shape
    p = patches.reshape(b, oh, ow, cin, kh, kw)
    p = p.transpose(0, 1, 2, 4, 5, 3)  # -> (kh, kw, cin)
    return p.reshape(b * oh * ow, k), (b, oh, ow)


def binary_conv(x, w, cfg: TrainConfig, first=False, stride=1,
                padding="SAME"):
    """x: (B,H,W,C); w: (kh,kw,C,F).  Returns (B,OH,OW,F).

    Lowers to im2col + the binary matmul op, so both fwd and bwd run
    through the paper's GEMM path (hardware-adaptation: TPUs convolve
    on the MXU via exactly this patch-GEMM form).
    """
    kh, kw, cin, f = w.shape
    cols, (b, oh, ow) = im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(kh * kw * cin, f)
    op = first_matmul_op if first else binary_matmul_op
    y = op(cols, wmat, cfg)
    return y.reshape(b, oh, ow, f)


def maxpool2(x):
    """2x2 max pool, NHWC.  Autodiff produces the argmax-mask backward
    whose mask the memory model prices as 1-bit ('Pooling masks')."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def bn_channelwise(y, beta, cfg: TrainConfig):
    """Apply batchnorm_op over channels for 2-D or 4-D activations.
    4-D activations fold (B,H,W) into the batch axis — exactly the
    paper's 'rows span a batch's feature maps' convention."""
    if y.ndim == 2:
        return batchnorm_op(y, beta, cfg)
    b, h, w, c = y.shape
    out = batchnorm_op(y.reshape(b * h * w, c), beta, cfg)
    return out.reshape(b, h, w, c)


# ---------------------------------------------------------------------
# Loss head
# ---------------------------------------------------------------------

def softmax_xent(logits, y_onehot):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def accuracy(logits, y_onehot):
    return jnp.mean(
        (jnp.argmax(logits, -1) == jnp.argmax(y_onehot, -1)).astype(
            jnp.float32
        )
    )
