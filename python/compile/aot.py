"""AOT export: lower every (model, algo, optimizer, batch) train/eval
step variant to HLO **text** + a JSON manifest + binary goldens.

HLO text — never `lowered.compiler_ir('hlo').serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (the version the `xla` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.

Artifacts (per variant `<name>`):
    artifacts/<name>.hlo.txt     HLO text, loaded by rust runtime
    artifacts/<name>.meta.json   positional input/output manifest
    artifacts/<name>.golden.bin  (selected variants) flat little-endian
                                 f32 dump of one fixed-seed step's
                                 inputs and outputs, offsets in meta —
                                 the Rust side's numerical ground truth

Python runs ONCE at build time (`make artifacts`); the rust binary is
self-contained afterwards.
"""

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import layers as L
from . import models as M
from . import train_step as T

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ----------------------------------------------------------- manifest

def _param_names(spec):
    names = []
    for i in range(spec.num_param_layers()):
        names += [f"w{i}", f"beta{i}"]
    return names


def _opt_names(spec, optimizer):
    n = spec.num_param_layers()
    if optimizer == "adam":
        return (["t"] + [f"m_{x}" for i in range(n) for x in (f"w{i}", f"beta{i}")]
                + [f"v_{x}" for i in range(n) for x in (f"w{i}", f"beta{i}")])
    if optimizer == "sgd":
        return [f"vel_{x}" for i in range(n) for x in (f"w{i}", f"beta{i}")]
    if optimizer == "bop":
        return ([f"ema_w{i}" for i in range(n)] + ["t"]
                + [f"bm_beta{i}" for i in range(n)]
                + [f"bv_beta{i}" for i in range(n)])
    raise ValueError(optimizer)


@dataclasses.dataclass
class Variant:
    model: str
    algo: str            # ablation name (TrainConfig.ablation key)
    optimizer: str       # 'adam' | 'sgd' | 'bop' (train only)
    batch: int
    kind: str = "train"  # 'train' | 'eval'
    pallas: bool = False
    golden: bool = False

    @property
    def name(self):
        bits = [self.model, self.algo]
        if self.kind == "train":
            bits.append(self.optimizer)
        bits.append(f"b{self.batch}")
        if self.pallas:
            bits.append("pallas")
        if self.kind == "eval":
            bits.append("eval")
        return "_".join(bits)


def build_variant(v: Variant, outdir: str):
    spec = M.get_model(v.model)
    cfg = dataclasses.replace(L.TrainConfig.ablation(v.algo),
                              use_pallas=v.pallas)
    xspec = jax.ShapeDtypeStruct((v.batch,) + spec.input_shape, jnp.float32)
    yspec = jax.ShapeDtypeStruct((v.batch, spec.classes), jnp.float32)
    pshapes = [s for pair in M.param_shapes(spec) for s in pair]
    pspecs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in pshapes]

    inputs, outputs = [], []

    def add(lst, names, shapes, kind):
        for nm, sh in zip(names, shapes):
            lst.append({"name": nm, "shape": list(sh), "kind": kind})

    if v.kind == "train":
        flat, nparams, nopt = T.make_flat_train_step(spec, cfg, v.optimizer)
        oshapes = T.opt_state_shapes(spec, v.optimizer)
        ospecs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in oshapes]
        args = pspecs + ospecs + [
            xspec, yspec, jax.ShapeDtypeStruct((), jnp.float32)]
        add(inputs, _param_names(spec), pshapes, "param")
        add(inputs, _opt_names(spec, v.optimizer), oshapes, "opt")
        add(inputs, ["x"], [xspec.shape], "x")
        add(inputs, ["y"], [yspec.shape], "y")
        add(inputs, ["lr"], [()], "lr")
        add(outputs, _param_names(spec), pshapes, "param")
        add(outputs, _opt_names(spec, v.optimizer), oshapes, "opt")
        add(outputs, ["loss", "acc"], [(), ()], "metric")
    else:
        flat, nparams = T.make_flat_eval_step(spec, cfg)
        args = pspecs + [xspec, yspec]
        add(inputs, _param_names(spec), pshapes, "param")
        add(inputs, ["x"], [xspec.shape], "x")
        add(inputs, ["y"], [yspec.shape], "y")
        add(outputs, ["loss", "acc"], [(), ()], "metric")

    lowered = jax.jit(flat).lower(*args)
    hlo = to_hlo_text(lowered)
    with open(os.path.join(outdir, v.name + ".hlo.txt"), "w") as f:
        f.write(hlo)

    meta = {
        "name": v.name,
        "model": v.model,
        "algo": v.algo,
        "optimizer": v.optimizer if v.kind == "train" else None,
        "kind": v.kind,
        "batch": v.batch,
        "classes": spec.classes,
        "input_shape": list(spec.input_shape),
        "use_pallas": v.pallas,
        "inputs": inputs,
        "outputs": outputs,
    }

    if v.golden:
        meta["golden"] = dump_golden(v, spec, cfg, flat, outdir)

    with open(os.path.join(outdir, v.name + ".meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return len(hlo)


def dump_golden(v, spec, cfg, flat, outdir):
    """One fixed-seed step: dump concrete inputs + outputs as flat
    little-endian f32 (inputs first, then outputs, in manifest order)."""
    key = jax.random.PRNGKey(42)
    params = M.init_params(spec, key)
    if v.kind == "train" and v.optimizer == "bop":
        params = T.init_bop_weights(params)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (v.batch,) + spec.input_shape, jnp.float32)
    labels = jax.random.randint(ky, (v.batch,), 0, spec.classes)
    y = jax.nn.one_hot(labels, spec.classes)
    if v.kind == "train":
        opt = T.init_opt_state(spec, v.optimizer)
        concrete = params + opt + [x, y, jnp.float32(0.001)]
    else:
        concrete = params + [x, y]
    outs = jax.jit(flat)(*concrete)

    blob = bytearray()
    sections = []
    for arrs in (concrete, list(outs)):
        for a in arrs:
            a = np.asarray(a, np.float32)
            sections.append({"offset": len(blob) // 4, "len": int(a.size)})
            blob += a.tobytes()
    path = os.path.join(outdir, v.name + ".golden.bin")
    with open(path, "wb") as f:
        f.write(bytes(blob))
    return {"file": v.name + ".golden.bin", "sections": sections,
            "n_inputs": len(concrete), "n_outputs": len(outs)}


# ------------------------------------------------------- variant sets

def variant_set(which: str):
    vs = []
    A = "adam"

    def train(model, algo, opt=A, batch=100, **kw):
        vs.append(Variant(model, algo, opt, batch, "train", **kw))

    def evalv(model, algo, batch=200, **kw):
        vs.append(Variant(model, algo, A, batch, "eval", **kw))

    # --- core: quickstart + golden verification + e2e example ---
    train("mlp_mini", "standard", batch=64, golden=True)
    train("mlp_mini", "proposed", batch=64, golden=True)
    train("mlp_mini", "proposed", batch=64, pallas=True, golden=True)
    evalv("mlp_mini", "standard", batch=64)
    evalv("mlp_mini", "proposed", batch=64)
    train("mlp", "standard", batch=100)
    train("mlp", "proposed", batch=100)
    train("mlp", "proposed", batch=100, pallas=True)
    evalv("mlp", "standard", batch=200)
    evalv("mlp", "proposed", batch=200)
    if which == "core":
        return vs

    # --- Table 3/4: model x dataset accuracy (proposed vs standard) ---
    for model in ("cnv_mini", "binarynet_mini"):
        for algo in ("standard", "proposed"):
            train(model, algo, batch=100)
            evalv(model, algo, batch=200)
    # Table 3's non-binary reference networks (robustness asymmetry)
    for model in ("mlp_mini", "cnv_mini", "binarynet_mini"):
        for algo in ("nn_standard", "nn_proposed"):
            b = 64 if model == "mlp_mini" else 100
            train(model, algo, batch=b)
            evalv(model, algo, batch=200 if model != "mlp_mini" else 64)
    vs.append(Variant("cnv_mini", "proposed", A, 100, "train",
                      pallas=True, golden=True))

    # --- Table 5 ablation: optimizer x data representation ---
    for opt in ("adam", "sgd", "bop"):
        for algo in ("standard", "f16", "boolgrad_l2", "boolgrad_l1",
                     "proposed"):
            if (opt, algo) in (("adam", "standard"), ("adam", "proposed")):
                continue  # already emitted above
            train("binarynet_mini", algo, opt=opt, batch=100)
    for algo in ("f16", "boolgrad_l2", "boolgrad_l1"):
        evalv("binarynet_mini", algo, batch=200)

    # --- Table 6: ImageNet-class residual models, per-approximation ---
    for model in ("resnete_mini", "bireal_mini"):
        for algo in ("standard", "f16", "boolgrad_l2", "boolgrad_l1",
                     "proposed"):
            # goldens on the reconciled-apply_model variants so
            # rust/tests/engine_parity.rs::residual_golden_loss_* has
            # ground truth to replay (see the Makefile blocker note)
            train(model, algo, batch=64, golden=algo == "standard")
            evalv(model, algo, batch=100)

    # --- Fig. 2: batch-size sweep (3 optimizers x 2 algos x 3 sizes) ---
    for opt in ("adam", "sgd", "bop"):
        for algo in ("standard", "proposed"):
            for b in (16, 64, 256):
                train("binarynet_mini", algo, opt=opt, batch=b)

    return vs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="full", choices=["core", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated variant-name substrings")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    vs = variant_set(args.set)
    seen = set()
    vs = [v for v in vs if not (v.name in seen or seen.add(v.name))]
    if args.only:
        keys = args.only.split(",")
        vs = [v for v in vs if any(k in v.name for k in keys)]

    for i, v in enumerate(vs):
        n = build_variant(v, args.out)
        print(f"[{i + 1}/{len(vs)}] {v.name}: {n} chars", flush=True)
    # index reflects everything on disk (merge across --only runs)
    names = sorted(
        f[: -len(".meta.json")]
        for f in os.listdir(args.out)
        if f.endswith(".meta.json")
    )
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(names, f, indent=1)
    print(f"wrote {len(vs)} artifacts to {args.out} (index: {len(names)})")


if __name__ == "__main__":
    main()
