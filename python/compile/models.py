"""L2 model zoo: the paper's benchmark networks, parameterized.

Each model is a list of layer specs plus pure `init` / `apply`
functions over a flat parameter list `[(W_0, beta_0), ...]` — flat so
the Rust coordinator can marshal parameters positionally through the
AOT HLO boundary (ordering recorded in the artifact manifest).

Paper models:
    MLP        5 fully-connected layers, 256/hidden (MNIST)
    CNV        FINN's 6-conv + 3-FC network (CIFAR-10 / SVHN)
    BinaryNet  Courbariaux & Bengio's VGG-like network
    ResNetE-18 / Bi-Real-18   binary residual nets with f32 skips

`*_mini` variants shrink widths/depths so a full AOT train step
executes in milliseconds on the CPU PJRT client; the *full-scale*
graphs (for the memory model) live in rust/src/models/, which mirrors
these topologies exactly.
"""

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                      # 'dense' | 'conv' | 'pool' | 'flatten' | 'residual'
    out: int = 0                   # output channels / units
    kernel: int = 3                # conv kernel size
    stride: int = 1
    first: bool = False            # unquantized-input layer
    bireal: bool = False           # skip around every conv (vs block)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    input_shape: Tuple[int, ...]   # per-sample, e.g. (784,) or (16,16,3)
    classes: int
    layers: List[LayerSpec]

    def num_param_layers(self):
        """Number of (W, beta) pairs — ResNetE residual blocks hold
        two convs per skip, Bi-Real blocks one."""
        n = 0
        for l in self.layers:
            if l.kind in ("dense", "conv"):
                n += 1
            elif l.kind == "residual":
                n += 1 if l.bireal else 2
        return n


# ---------------------------------------------------------------- zoo

def mlp(name="mlp", inp=784, hidden=256, depth=5, classes=10):
    """Paper's MNIST MLP: `depth` dense layers, `hidden` units each."""
    specs = []
    for i in range(depth - 1):
        specs.append(LayerSpec("dense", out=hidden, first=(i == 0)))
    specs.append(LayerSpec("dense", out=classes))
    return ModelSpec(name, (inp,), classes, specs)


def mlp_mini():
    return mlp(name="mlp_mini", inp=64, hidden=64, depth=3)


def cnv(name="cnv", size=32, ch=(64, 64, 128, 128, 256, 256),
        fc=(512, 512), classes=10, in_ch=3):
    """FINN's CNV: 6 conv (pool after each pair) + 3 FC."""
    specs = []
    for i, c in enumerate(ch):
        specs.append(LayerSpec("conv", out=c, kernel=3, first=(i == 0)))
        if i % 2 == 1:
            specs.append(LayerSpec("pool"))
    specs.append(LayerSpec("flatten"))
    for u in fc:
        specs.append(LayerSpec("dense", out=u))
    specs.append(LayerSpec("dense", out=classes))
    return ModelSpec(name, (size, size, in_ch), classes, specs)


def cnv_mini():
    return cnv(name="cnv_mini", size=16, ch=(16, 16, 32, 32), fc=(64,))


def binarynet(name="binarynet", size=32,
              ch=(128, 128, 256, 256, 512, 512), fc=(1024, 1024),
              classes=10, in_ch=3):
    """Courbariaux & Bengio's VGG-like BinaryNet."""
    return cnv(name=name, size=size, ch=ch, fc=fc, classes=classes,
               in_ch=in_ch)


def binarynet_mini():
    return binarynet(name="binarynet_mini", size=16,
                     ch=(16, 16, 32, 32), fc=(64, 64))


def resnet_binary(name="resnete_mini", size=16, stem=16, blocks=4,
                  classes=10, bireal=False, in_ch=3):
    """ResNetE-18 / Bi-Real-18 style: f32 stem conv, binary residual
    convs with high-precision (identity) skip connections, global
    pool, dense classifier.  Channel count doubles halfway."""
    specs = [LayerSpec("conv", out=stem, kernel=3, first=True)]
    c = stem
    for i in range(blocks):
        if i == blocks // 2:
            c *= 2
        specs.append(LayerSpec("residual", out=c, kernel=3,
                               bireal=bireal))
    specs.append(LayerSpec("flatten"))
    specs.append(LayerSpec("dense", out=classes))
    return ModelSpec(name, (size, size, in_ch), classes, specs)


def bireal_mini():
    return resnet_binary(name="bireal_mini", bireal=True)


ZOO = {
    "mlp": mlp,
    "mlp_mini": mlp_mini,
    "cnv": cnv,
    "cnv_mini": cnv_mini,
    "binarynet": binarynet,
    "binarynet_mini": binarynet_mini,
    "resnete_mini": resnet_binary,
    "bireal_mini": bireal_mini,
}


def get_model(name: str) -> ModelSpec:
    return ZOO[name]()


# --------------------------------------------------------------- init

def _glorot(key, shape):
    fan_in = shape[0] if len(shape) == 2 else shape[0] * shape[1] * shape[2]
    fan_out = shape[-1]
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def param_shapes(spec: ModelSpec):
    """[(w_shape, beta_shape), ...] in apply order."""
    shapes = []
    if len(spec.input_shape) == 1:
        feat = spec.input_shape[0]
        spatial = None
        ch = None
    else:
        h, w, ch = spec.input_shape
        spatial = (h, w)
        feat = None
    for l in spec.layers:
        if l.kind == "conv":
            shapes.append(((l.kernel, l.kernel, ch, l.out), (l.out,)))
            ch = l.out
        elif l.kind == "residual":
            # first conv may double channels; ResNetE blocks add a
            # second (channel-preserving, stride-1) conv under the
            # same skip
            shapes.append(((l.kernel, l.kernel, ch, l.out), (l.out,)))
            ch = l.out
            if l.stride > 1:
                # SAME conv: out = ceil(in / stride)
                spatial = (-(-spatial[0] // l.stride), -(-spatial[1] // l.stride))
            if not l.bireal:
                shapes.append(((l.kernel, l.kernel, ch, ch), (ch,)))
        elif l.kind == "pool":
            spatial = (spatial[0] // 2, spatial[1] // 2)
        elif l.kind == "flatten":
            feat = spatial[0] * spatial[1] * ch
        elif l.kind == "dense":
            shapes.append(((feat, l.out), (l.out,)))
            feat = l.out
    return shapes


def init_params(spec: ModelSpec, key) -> List[jnp.ndarray]:
    """Glorot-initialized flat parameter list [W0, beta0, W1, ...]."""
    flat = []
    for wshape, bshape in param_shapes(spec):
        key, sub = jax.random.split(key)
        flat.append(_glorot(sub, wshape))
        flat.append(jnp.zeros(bshape, jnp.float32))
    return flat


# -------------------------------------------------------------- apply

def apply_model(spec: ModelSpec, cfg: L.TrainConfig, params, x):
    """Forward pass -> logits.  `params` is the flat [W, beta, ...]
    list from init_params.  Backward behaviour (what is retained, at
    which precision) is fully determined by the custom-vjp layers."""
    it = iter(range(0, len(params), 2))
    pi = lambda: next(it)

    def take():
        i = pi()
        return params[i], params[i + 1]

    h = x
    binarize_next = False   # first layer consumes real inputs
    for l in spec.layers:
        if l.kind == "dense":
            w, beta = take()
            if h.ndim > 2:
                h = h.reshape(h.shape[0], -1)
            hin = L.binarize(h, cfg) if binarize_next else h
            op = L.first_matmul_op if l.first else L.binary_matmul_op
            y = op(hin, w, cfg)
            h = L.bn_channelwise(y, beta, cfg)
            binarize_next = True
        elif l.kind == "conv":
            w, beta = take()
            hin = L.binarize(h, cfg) if binarize_next else h
            y = L.binary_conv(hin, w, cfg, first=l.first, stride=l.stride)
            h = L.bn_channelwise(y, beta, cfg)
            binarize_next = True
        elif l.kind == "residual":
            # Bi-Real: skip around the single conv; ResNetE: one skip
            # around the 2-conv block, the *second* conv at stride 1
            # (the lowering convention the Rust engines implement —
            # the old code applied l.stride to both block convs and
            # skipped around each conv separately, which is why the
            # HLO runtime rejected residual train-side goldens; see
            # ROADMAP PR-4/PR-5 notes).  Skips are high-precision
            # (f32) — the accuracy enhancement the paper incorporates
            # (Sec. 2) — and the downsample shortcut is
            # parameter-free: strided 1×1 subsample + channel
            # duplication, matching naive::ops::skip_add.
            def conv_bn(hh, stride):
                w, beta = take()
                y = L.binary_conv(L.binarize(hh, cfg), w, cfg,
                                  first=False, stride=stride)
                return L.bn_channelwise(y, beta, cfg)

            def add_skip(y, skip):
                if l.stride > 1:
                    # strided subsample picks rows/cols 0, s, 2s, ...
                    # (out = ceil(in/s), the conv path's grid)
                    skip = skip[:, ::l.stride, ::l.stride, :]
                if skip.shape[-1] != y.shape[-1]:
                    # parameter-free channel-doubling expansion
                    skip = jnp.concatenate([skip, skip], axis=-1)
                return y + skip

            if l.bireal:
                h = add_skip(conv_bn(h, l.stride), h)
            else:
                h = add_skip(conv_bn(conv_bn(h, l.stride), 1), h)
            binarize_next = True
        elif l.kind == "pool":
            h = L.maxpool2(h)
        elif l.kind == "flatten":
            h = h.reshape(h.shape[0], -1)
    return h
